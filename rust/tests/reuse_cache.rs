//! Reuse-cache integration suite. The load-bearing guarantees:
//!
//! * with `[cache]` absent or `enabled = false` the fleet scheduler is
//!   **bit-identical** to the pre-cache (PR 2) scheduler,
//! * a **cross-session hit actually skips the wire frame** — the TCP
//!   cloud server sees one request fewer for every hit,
//! * **chaos + warm cache beats chaos + cold cache**: through an uplink
//!   outage the warm fleet keeps serving cloud-grade chunks from the
//!   store, and through a reply-drop window it strictly undercuts the
//!   cold fleet's timeout bill,
//! * **eviction replays exactly** under a fixed seed,
//! * the **sharded store is bit-identical** to the single-map (PR 5)
//!   store while nothing evicts, and still completes + replays under
//!   per-shard eviction pressure,
//! * the per-session tier works without the fleet-shared tier
//!   (`cache.shared = false`).

use rapid::config::{PolicyKind, SystemConfig};
use rapid::experiments::reuse;
use rapid::faults::{FaultEngine, FaultPlan};
use rapid::net::{CloudClient, CloudServer};
use rapid::robot::TaskKind;
use rapid::serve::{Fleet, FleetResult};
use rapid::vla::AnalyticBackend;
use std::sync::atomic::Ordering;

fn fleet_sys(n: usize, max_batch: usize) -> SystemConfig {
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = n;
    sys.fleet.max_batch = max_batch;
    sys.fleet.max_inflight = 16;
    sys
}

fn total_lat(res: &FleetResult) -> f64 {
    res.summary().fleet.total_lat_mean
}

fn total_hits(res: &FleetResult) -> u64 {
    res.sessions.iter().flat_map(|s| s.episodes.iter()).map(|m| m.cache_hits).sum()
}

fn assert_all_complete(res: &FleetResult, task: TaskKind, tag: &str) {
    for s in &res.sessions {
        for (ep, m) in s.episodes.iter().enumerate() {
            assert_eq!(
                m.steps,
                task.seq_len(),
                "{tag}: session {} episode {ep} wedged at step {}",
                s.session,
                m.steps
            );
        }
    }
}

// ------------------------------------------------------------- identity

#[test]
fn disabled_cache_is_bit_identical_to_pr2_baseline() {
    // `[cache]` absent (the default SystemConfig) vs a fully-knobbed but
    // disabled section: per-session metrics must match to the last bit
    let sys = fleet_sys(6, 4);
    let baseline = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();

    let mut disabled = sys.clone();
    disabled.cache.enabled = false;
    disabled.cache.capacity = 7;
    disabled.cache.ttl_rounds = 3;
    disabled.cache.seed = 999;
    disabled.cache.quant = 0.001;
    let run = Fleet::local(&disabled, TaskKind::PickPlace, PolicyKind::Rapid).run();

    assert_eq!(baseline.stats.rounds, run.stats.rounds);
    assert_eq!(baseline.stats.batches, run.stats.batches);
    assert_eq!(baseline.stats.batched_requests, run.stats.batched_requests);
    assert_eq!(baseline.endpoint_dispatches, run.endpoint_dispatches);
    assert!(run.cache.is_zero(), "{:?}", run.cache);
    for (sa, sb) in baseline.sessions.iter().zip(run.sessions.iter()) {
        for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
            assert_eq!(ma.latency_columns(), mb.latency_columns(), "session {}", sa.session);
            assert_eq!(ma.cloud_events, mb.cloud_events);
            assert_eq!(ma.edge_events, mb.edge_events);
            assert_eq!(ma.rms_error, mb.rms_error);
            assert_eq!(ma.success, mb.success);
            assert_eq!((ma.cache_hits, ma.cache_misses), (0, 0));
            assert_eq!((mb.cache_hits, mb.cache_misses), (0, 0));
        }
    }
}

#[test]
fn enabled_cache_with_an_offload_free_policy_changes_nothing() {
    // Edge-Only never routes to the cloud: no probes, no admissions, and
    // the enabled store stays untouched — the run equals the baseline
    let sys = fleet_sys(4, 4);
    let baseline = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::EdgeOnly).run();
    let mut cached = sys.clone();
    cached.cache.enabled = true;
    let run = Fleet::local(&cached, TaskKind::PickPlace, PolicyKind::EdgeOnly).run();
    assert!(run.cache.is_zero(), "{:?}", run.cache);
    for (sa, sb) in baseline.sessions.iter().zip(run.sessions.iter()) {
        for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
            assert_eq!(ma.latency_columns(), mb.latency_columns());
            assert_eq!(ma.rms_error, mb.rms_error);
        }
    }
}

// ------------------------------------------------------------- the wire

#[test]
fn cross_session_hit_skips_the_wire_frame() {
    // one real TCP endpoint; 8 lockstep Cloud-Only sessions with a batch
    // bound of 4: the first flush admits its replies, the back half of
    // the fleet hits the store in the same round — and every hit is one
    // request the server never sees
    let server =
        CloudServer::start("127.0.0.1:0", 8, || Box::new(AnalyticBackend::cloud(300))).unwrap();
    let task = TaskKind::PickPlace;
    let refills = ((task.seq_len() + rapid::CHUNK - 1) / rapid::CHUNK) as u64;

    let mut sys = fleet_sys(8, 4);
    sys.cache.enabled = true;
    let client = CloudClient::connect(&server.addr.to_string()).unwrap();
    let res = Fleet::remote(&sys, task, PolicyKind::CloudOnly, vec![client]).run();
    assert_all_complete(&res, task, "cached remote");

    let hits = total_hits(&res);
    assert_eq!(hits, res.cache.hits, "episode and store hit counts agree");
    assert!(hits >= 4, "round-0 cross-session hits expected, got {hits}");
    let served = server.stats().requests.load(Ordering::Relaxed);
    assert_eq!(
        served + hits,
        8 * refills,
        "wire requests + cache hits must partition the offload schedule"
    );
    assert!(served < 8 * refills, "the server must see fewer frames than the schedule");
    server.shutdown();

    // the cache-off control run pays the wire for every single refill
    let server2 =
        CloudServer::start("127.0.0.1:0", 8, || Box::new(AnalyticBackend::cloud(300))).unwrap();
    let mut off = sys.clone();
    off.cache.enabled = false;
    let client2 = CloudClient::connect(&server2.addr.to_string()).unwrap();
    let base = Fleet::remote(&off, task, PolicyKind::CloudOnly, vec![client2]).run();
    assert_all_complete(&base, task, "uncached remote");
    assert_eq!(server2.stats().requests.load(Ordering::Relaxed), 8 * refills);
    server2.shutdown();
}

// ---------------------------------------------------------------- chaos

#[test]
fn outage_warm_cache_keeps_serving_where_cold_defers() {
    // episode 1 warms the store; a long uplink outage covers episode 2
    // entirely. The cold fleet can only defer every refill to its (empty,
    // 0 GB) Cloud-Only edge slice; the warm fleet serves cloud-grade
    // chunks from the store — every hit is a deferral that never happened
    let mut sys = fleet_sys(6, 4);
    sys.fleet.episodes_per_session = 2;
    sys.cache.enabled = true;
    sys.cache.ttl_rounds = 512;
    sys.cache.capacity = 1024;
    let task = TaskKind::PickPlace;
    let plan = FaultPlan::none().outage(45, 400);

    let warm = Fleet::local_with_faults(
        &sys,
        task,
        PolicyKind::CloudOnly,
        FaultEngine::new(plan.clone(), 1, 250.0, 2),
    )
    .run();
    let mut cold_sys = sys.clone();
    cold_sys.cache.enabled = false;
    let cold = Fleet::local_with_faults(
        &cold_sys,
        task,
        PolicyKind::CloudOnly,
        FaultEngine::new(plan, 1, 250.0, 2),
    )
    .run();

    assert_all_complete(&warm, task, "warm outage");
    assert_all_complete(&cold, task, "cold outage");
    assert!(warm.stats.outage_rounds > 0 && cold.stats.outage_rounds > 0);
    // episode 2 starts inside the outage with the exact initial signature
    // episode 1 admitted at round 0: at least one guaranteed hit/session
    assert!(warm.cache.hits >= 6, "outage-window hits expected: {:?}", warm.cache);
    assert!(
        warm.stats.deferred_offloads < cold.stats.deferred_offloads,
        "hits must replace deferrals: warm {} vs cold {}",
        warm.stats.deferred_offloads,
        cold.stats.deferred_offloads
    );
    assert_eq!(cold.cache.hits, 0);
}

#[test]
fn drop_window_warm_cache_strictly_undercuts_cold() {
    // every reply is dropped from round 40 on (single endpoint, no
    // retries): episode 2 offloads each cost the cold fleet a full
    // timeout + edge failover, while the warm fleet serves the steps it
    // cached during episode 1 at probe latency — strictly lower fleet
    // mean latency
    let mut sys = fleet_sys(6, 4);
    sys.fleet.episodes_per_session = 2;
    sys.cache.enabled = true;
    sys.cache.ttl_rounds = 512;
    sys.cache.capacity = 1024;
    let task = TaskKind::PickPlace;
    let plan = FaultPlan::none().drop_replies(40, u64::MAX, 1.0);

    let warm = Fleet::local_with_faults(
        &sys,
        task,
        PolicyKind::CloudOnly,
        FaultEngine::new(plan.clone(), 7, 250.0, 0),
    )
    .run();
    let mut cold_sys = sys.clone();
    cold_sys.cache.enabled = false;
    let cold = Fleet::local_with_faults(
        &cold_sys,
        task,
        PolicyKind::CloudOnly,
        FaultEngine::new(plan, 7, 250.0, 0),
    )
    .run();

    assert_all_complete(&warm, task, "warm drops");
    assert_all_complete(&cold, task, "cold drops");
    assert!(warm.cache.hits >= 6, "episode-2 hits expected: {:?}", warm.cache);
    assert!(cold.stats.dropped_replies > 0 && warm.stats.dropped_replies > 0);
    assert!(
        total_lat(&warm) < total_lat(&cold),
        "every hit replaces a charged timeout: warm {} vs cold {}",
        total_lat(&warm),
        total_lat(&cold)
    );
}

// ------------------------------------------------------------- eviction

#[test]
fn eviction_pressure_replays_exactly() {
    // a 2-entry store under a 6-session fleet churns constantly; the
    // seeded eviction stream must make the whole run reproducible
    let mut sys = fleet_sys(6, 4);
    sys.cache.enabled = true;
    sys.cache.capacity = 2;
    let run = || Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    let a = run();
    let b = run();
    assert!(a.cache.evictions > 0, "capacity 2 must evict: {:?}", a.cache);
    assert_eq!(a.cache, b.cache, "store counters replay");
    assert_eq!(a.stats.rounds, b.stats.rounds);
    assert_eq!(a.stats.batched_requests, b.stats.batched_requests);
    for (sa, sb) in a.sessions.iter().zip(b.sessions.iter()) {
        for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
            assert_eq!(ma.latency_columns(), mb.latency_columns(), "session {}", sa.session);
            assert_eq!(ma.cache_hits, mb.cache_hits);
            assert_eq!(ma.rms_error, mb.rms_error);
        }
    }
}

// ------------------------------------------------------------- sharding

#[test]
fn sharded_store_fleet_is_bit_identical_when_nothing_evicts() {
    // sharding only re-partitions the capacity and eviction streams; while
    // no shard ever fills, neither store draws a single eviction and a
    // fleet over the 8-shard store must replay the single-map (PR 5)
    // scheduler to the last bit — full stats, flush causes, per-episode
    // trajectories
    let task = TaskKind::PickPlace;
    let mut sys = fleet_sys(8, 4);
    sys.cache.enabled = true;
    // capacity/8 = 512 per shard > every distinct key the run can admit,
    // so no shard can fill even if hashing piled all keys into one
    sys.cache.capacity = 4096;
    let baseline = Fleet::local(&sys, task, PolicyKind::CloudOnly).run();

    let mut sharded_sys = sys.clone();
    sharded_sys.cache.shards = 8;
    let run = Fleet::local(&sharded_sys, task, PolicyKind::CloudOnly).run();

    assert_eq!(baseline.cache, run.cache, "store counters must match");
    assert_eq!(baseline.cache.evictions, 0, "the identity argument needs an eviction-free run");
    assert!(run.cache.hits >= 4, "the sharded run still serves hits: {:?}", run.cache);
    assert_eq!(baseline.stats.rounds, run.stats.rounds);
    assert_eq!(baseline.stats.batches, run.stats.batches);
    assert_eq!(baseline.stats.batched_requests, run.stats.batched_requests);
    assert_eq!(baseline.stats.full_flushes, run.stats.full_flushes);
    assert_eq!(baseline.stats.deadline_flushes, run.stats.deadline_flushes);
    assert_eq!(baseline.stats.drain_flushes, run.stats.drain_flushes);
    assert_eq!(baseline.endpoint_dispatches, run.endpoint_dispatches);
    for (sa, sb) in baseline.sessions.iter().zip(run.sessions.iter()) {
        assert_eq!(sa.arrival_round, sb.arrival_round);
        assert_eq!(sa.departure_round, sb.departure_round);
        for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
            assert_eq!(ma.latency_columns(), mb.latency_columns(), "session {}", sa.session);
            assert_eq!(ma.cloud_events, mb.cloud_events);
            assert_eq!(ma.cache_hits, mb.cache_hits);
            assert_eq!(ma.rms_error, mb.rms_error);
            assert_eq!(ma.success, mb.success);
        }
    }
}

#[test]
fn sharded_store_fleet_under_eviction_pressure_completes() {
    // 8 entries over 4 shards (2 per shard) churn constantly; the run
    // must still finish every episode, and the per-shard seeded eviction
    // streams must make the whole run replay exactly
    let task = TaskKind::PickPlace;
    let mut sys = fleet_sys(6, 4);
    sys.cache.enabled = true;
    sys.cache.capacity = 8;
    sys.cache.shards = 4;
    let run = || Fleet::local(&sys, task, PolicyKind::CloudOnly).run();
    let a = run();
    let b = run();
    assert_all_complete(&a, task, "sharded pressure");
    assert!(a.cache.evictions > 0, "capacity 8 over 4 shards must evict: {:?}", a.cache);
    assert_eq!(a.cache, b.cache, "per-shard eviction streams replay");
    assert_eq!(a.stats.rounds, b.stats.rounds);
    assert_eq!(a.stats.batched_requests, b.stats.batched_requests);
}

// ------------------------------------------------------------- the tiers

#[test]
fn unshared_store_restricts_reuse_to_the_owning_session() {
    let task = TaskKind::PickPlace;
    let mut shared = fleet_sys(8, 4);
    shared.cache.enabled = true;
    let hits_shared = Fleet::local(&shared, task, PolicyKind::CloudOnly).run().cache.hits;
    assert!(hits_shared >= 4, "shared tier hits: {hits_shared}");

    let mut unshared = shared.clone();
    unshared.cache.shared = false;
    let hits_unshared = Fleet::local(&unshared, task, PolicyKind::CloudOnly).run().cache.hits;
    assert!(
        hits_unshared < hits_shared,
        "blocking the shared tier must cost hits: {hits_unshared} vs {hits_shared}"
    );

    // the per-session tier still works across a session's own episodes
    let mut own = unshared.clone();
    own.fleet.episodes_per_session = 2;
    own.cache.ttl_rounds = 512;
    let res = Fleet::local(&own, task, PolicyKind::CloudOnly).run();
    assert!(res.cache.hits >= 6, "episode 2 must reuse the session's own entries: {:?}", res.cache);
    assert_all_complete(&res, task, "per-session tier");
}

// ------------------------------------- the shipped config (acceptance)

#[test]
fn libero_toml_cache_arm_hits_and_wins_at_equal_success() {
    let src = std::fs::read_to_string("configs/libero.toml").expect("configs/libero.toml");
    let sys = SystemConfig::from_toml(&src).expect("libero.toml parses");
    assert!(!sys.cache.enabled, "the shipped config keeps the cache off by default");
    assert_eq!(sys.cache.capacity, 256, "libero.toml carries the [cache] knobs");

    let (_, rows) = reuse::run(&sys, TaskKind::PickPlace);
    let fleet_hits: u64 = rows.iter().map(|r| r.clean_cache.hits + r.chaos_cache.hits).sum();
    assert!(fleet_hits > 0, "the reuse table must show a nonzero fleet hit rate");
    for r in &rows {
        assert!(r.completed, "{:?} wedged", r.policy);
    }
    let cloud = rows.iter().find(|r| r.policy == PolicyKind::CloudOnly).unwrap();
    assert!(cloud.clean_cache.hits > 0);
    assert!(
        cloud.clean_on_lat < cloud.clean_off_lat,
        "cache-on must strictly lower mean episode latency: {} vs {}",
        cloud.clean_on_lat,
        cloud.clean_off_lat
    );
    // the acceptance pin: strictly lower latency *at equal task success*.
    // If a borderline episode ever flips under reuse, tighten the
    // divergence budget (cache.quant / cache.max_zscore) rather than
    // loosening this assert.
    assert_eq!(
        cloud.clean_on_success, cloud.clean_off_success,
        "the win must come at equal task success"
    );
}

//! Property-based tests over the coordinator invariants (DESIGN.md §7).
//!
//! No proptest crate exists in this offline environment, so this file
//! carries a minimal property-testing harness: seeded random generators
//! drive each property over many cases; a failure reports the seed so the
//! case replays deterministically.

use rapid::config::{DispatcherConfig, LinkConfig, NoiseLevel, PolicyKind, SystemConfig};
use rapid::dispatcher::{fusion, Cooldown, RapidDispatcher};
use rapid::net::Link;
use rapid::robot::{Jv, SensorFrame, TaskKind};
use rapid::util::{Pcg32, RollingStats};

const P_SEED_BASE: u64 = 0x5EED_CAFE;

/// Run a property over `$cases` seeded inputs; panic with the replayable
/// seed on the first failure.
macro_rules! seeded_forall {
    ($name:expr, $cases:expr, $prop:expr) => {
        for seed in 0..$cases as u64 {
            let mut rng = Pcg32::new(P_SEED_BASE ^ seed.wrapping_mul(0x9E3779B97F4A7C15), seed);
            if let Err(msg) = ($prop)(&mut rng) {
                panic!("property {} failed for seed {}: {}", $name, seed, msg);
            }
        }
    };
}

fn random_frame(rng: &mut Pcg32, step: usize) -> SensorFrame {
    SensorFrame {
        step,
        q: Jv::from_fn(|_| rng.range(-3.0, 3.0)),
        dq: Jv::from_fn(|_| rng.range(-2.5, 2.5)),
        tau: Jv::from_fn(|_| rng.range(-20.0, 20.0)),
    }
}

/// Invariant #3: phase weights form a simplex for arbitrary velocity.
#[test]
fn prop_phase_weights_simplex() {
    seeded_forall!("weights_simplex", 500, |rng: &mut Pcg32| {
        let v = match rng.below(10) {
            0 => f64::NAN,
            1 => f64::INFINITY,
            2 => -1.0,
            _ => rng.range(0.0, 10.0),
        };
        let vmax = rng.range(0.1, 5.0);
        let w = fusion::phase_weights(v, vmax);
        if !((w.w_a + w.w_tau - 1.0).abs() < 1e-12 && (0.0..=1.0).contains(&w.w_a)) {
            return Err(format!("v={v} vmax={vmax} -> {w:?}"));
        }
        Ok(())
    });
}

/// Invariant #4: rolling stats match a naive recompute on random streams.
#[test]
fn prop_rolling_stats_match_naive() {
    seeded_forall!("rolling_naive", 100, |rng: &mut Pcg32| {
        let window = 1 + rng.below(64) as usize;
        let n = 10 + rng.below(200) as usize;
        let mut rs = RollingStats::new(window);
        let mut data = Vec::new();
        for i in 0..n {
            let mu = rng.range(-5.0, 5.0);
            let v = rng.normal_ms(mu, 3.0);
            data.push(v);
            rs.push(v);
            let lo = (i + 1).saturating_sub(window);
            let win = &data[lo..=i];
            let mean = win.iter().sum::<f64>() / win.len() as f64;
            let var = win.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / win.len() as f64;
            if (rs.mean() - mean).abs() > 1e-6 {
                return Err(format!("mean {} vs {} at i={i} w={window}", rs.mean(), mean));
            }
            if (rs.std() - var.sqrt()).abs() > 1e-6 {
                return Err(format!("std at i={i} w={window}"));
            }
        }
        Ok(())
    });
}

/// Invariant #2: after a dispatch, no second dispatch within C steps even
/// under adversarial sensor streams (unless C = 0).
#[test]
fn prop_cooldown_masks_dispatches() {
    seeded_forall!("cooldown", 60, |rng: &mut Pcg32| {
        let mut cfg = DispatcherConfig::default();
        cfg.cooldown = 1 + rng.below(20);
        let mut d = RapidDispatcher::new(&cfg, 0.05);
        let mut last_dispatch: Option<usize> = None;
        for step in 0..400 {
            d.observe(&random_frame(rng, step));
            let decision = d.decide(rng.chance(0.2));
            if decision == rapid::dispatcher::Decision::OffloadCloud {
                if let Some(prev) = last_dispatch {
                    let gap = step - prev;
                    if gap < cfg.cooldown as usize {
                        return Err(format!("dispatch gap {gap} < C={}", cfg.cooldown));
                    }
                }
                last_dispatch = Some(step);
            }
        }
        Ok(())
    });
}

/// Invariant #5: on any fixed trace, raising both thresholds never
/// increases the number of dispatches.
#[test]
fn prop_threshold_monotonicity() {
    seeded_forall!("threshold_monotone", 30, |rng: &mut Pcg32| {
        // one shared random trace
        let trace: Vec<SensorFrame> = (0..300).map(|i| random_frame(rng, i)).collect();
        let queue_empty: Vec<bool> = (0..300).map(|_| rng.chance(0.12)).collect();
        let count = |tc: f64, tr: f64| -> u64 {
            let mut cfg = DispatcherConfig::default();
            cfg.theta_comp = tc;
            cfg.theta_red = tr;
            cfg.cooldown = 0; // count raw dispatches
            let mut d = RapidDispatcher::new(&cfg, 0.05);
            let mut n = 0;
            for (f, &qe) in trace.iter().zip(queue_empty.iter()) {
                d.observe(f);
                if d.decide(qe) == rapid::dispatcher::Decision::OffloadCloud {
                    n += 1;
                }
            }
            n
        };
        let lo = (rng.range(0.1, 1.0), rng.range(0.1, 1.0));
        let hi = (lo.0 + rng.range(0.0, 2.0), lo.1 + rng.range(0.0, 2.0));
        let n_lo = count(lo.0, lo.1);
        let n_hi = count(hi.0, hi.1);
        if n_hi > n_lo {
            return Err(format!("thresholds {lo:?}->{hi:?}: dispatches {n_lo}->{n_hi}"));
        }
        Ok(())
    });
}

/// Invariant #1 + #6: for random policies/tasks/noise, episodes complete
/// with every step served, loads conserved, and the accounting identity.
#[test]
fn prop_episode_invariants() {
    let kinds = [
        PolicyKind::Rapid,
        PolicyKind::RapidNoComp,
        PolicyKind::RapidNoRed,
        PolicyKind::RapidStaticFusion,
        PolicyKind::EdgeOnly,
        PolicyKind::CloudOnly,
        PolicyKind::VisionBased,
    ];
    let tasks = [TaskKind::PickPlace, TaskKind::DrawerOpen, TaskKind::PegInsert];
    let noises = [NoiseLevel::Standard, NoiseLevel::VisualNoise, NoiseLevel::Distraction];
    seeded_forall!("episode_invariants", 24, |rng: &mut Pcg32| {
        let mut sys = SystemConfig::default();
        sys.scene.noise = noises[rng.below(3) as usize];
        sys.dispatcher.theta_comp = rng.range(0.1, 2.0);
        sys.dispatcher.theta_red = rng.range(0.1, 2.0);
        sys.dispatcher.cooldown = rng.below(24);
        let kind = kinds[rng.below(kinds.len() as u32) as usize];
        let task = tasks[rng.below(3) as usize];
        let seed = rng.next_u64();

        let strategy = rapid::policy::build(kind, &sys);
        let mut edge = rapid::vla::AnalyticBackend::edge(seed);
        let mut cloud = rapid::vla::AnalyticBackend::cloud(seed);
        let out =
            rapid::serve::run_episode(&sys, task, strategy, &mut edge, &mut cloud, seed, false);
        let m = &out.metrics;
        if m.steps != task.seq_len() {
            return Err(format!("{kind:?}/{task:?}: steps {} != {}", m.steps, task.seq_len()));
        }
        if m.events() == 0 {
            return Err("no inference events".into());
        }
        if !m.identity_holds(sys.total_model_gb) {
            return Err(format!("accounting identity violated: {m:?}"));
        }
        if !(m.edge_gb >= 0.0 && m.edge_gb <= sys.total_model_gb + 1e-9) {
            return Err(format!("edge load out of range: {}", m.edge_gb));
        }
        let (c, e, t) = m.latency_columns();
        if !(c.is_finite() && e.is_finite() && t.is_finite() && t >= 0.0) {
            return Err(format!("non-finite latency columns ({c}, {e}, {t})"));
        }
        Ok(())
    });
}

/// Invariant #8: whole-episode determinism for every policy kind.
#[test]
fn prop_episodes_deterministic() {
    seeded_forall!("determinism", 10, |rng: &mut Pcg32| {
        let kinds = [PolicyKind::Rapid, PolicyKind::VisionBased, PolicyKind::CloudOnly];
        let kind = kinds[rng.below(3) as usize];
        let seed = rng.next_u64();
        let sys = SystemConfig::default();
        let run = || {
            let strategy = rapid::policy::build(kind, &sys);
            let mut edge = rapid::vla::AnalyticBackend::edge(seed);
            let mut cloud = rapid::vla::AnalyticBackend::cloud(seed);
            rapid::serve::run_episode(
                &sys,
                TaskKind::PegInsert,
                strategy,
                &mut edge,
                &mut cloud,
                seed,
                false,
            )
            .metrics
        };
        let a = run();
        let b = run();
        if a.latency_columns() != b.latency_columns()
            || a.cloud_events != b.cloud_events
            || a.rms_error != b.rms_error
        {
            return Err(format!("{kind:?} non-deterministic"));
        }
        Ok(())
    });
}

/// Invariant #9: the fused trigger is monotone in both anomaly inputs —
/// raising either normalized score never lowers the importance and never
/// un-triggers a trigger (for any phase velocity / thresholds / fusion
/// mode).
#[test]
fn prop_fusion_monotone_in_anomaly_inputs() {
    seeded_forall!("fusion_monotone", 300, |rng: &mut Pcg32| {
        let mut cfg = DispatcherConfig::default();
        cfg.theta_comp = rng.range(0.05, 1.5);
        cfg.theta_red = rng.range(0.05, 1.5);
        cfg.z_gate = rng.range(0.5, 4.0);
        cfg.static_fusion = rng.chance(0.3);
        let v = rng.range(0.0, 3.0);
        let a = rng.range(0.0, 6.0);
        let t = rng.range(0.0, 6.0);
        let da = rng.range(0.0, 3.0);
        let dt = rng.range(0.0, 3.0);
        let base = fusion::evaluate(a, t, v, &cfg);
        let more = fusion::evaluate(a + da, t + dt, v, &cfg);
        if more.importance + 1e-12 < base.importance {
            return Err(format!(
                "importance decreased: {} -> {} (a={a}+{da}, t={t}+{dt}, v={v})",
                base.importance, more.importance
            ));
        }
        if base.triggered && !more.triggered {
            return Err(format!("trigger lost raising inputs: a={a}+{da} t={t}+{dt} v={v}"));
        }
        Ok(())
    });
}

/// Invariant #10: the chunk queue never exceeds its capacity (one chunk)
/// and its traffic statistics stay consistent under arbitrary
/// overwrite/pop sequences.
#[test]
fn prop_chunk_queue_bounded_by_capacity() {
    use rapid::dispatcher::{ChunkQueue, ChunkSource};
    seeded_forall!("queue_capacity", 200, |rng: &mut Pcg32| {
        let mut q = ChunkQueue::new();
        let mut popped = 0u64;
        let mut overwrites = 0u64;
        for step in 0..200 {
            if rng.chance(0.3) {
                let n = 1 + rng.below(rapid::CHUNK as u32) as usize;
                let actions: Vec<Jv> =
                    (0..n).map(|_| Jv::splat(rng.range(-1.0, 1.0))).collect();
                let src = if rng.chance(0.5) { ChunkSource::Edge } else { ChunkSource::Cloud };
                q.overwrite(&actions, src, step);
                overwrites += 1;
            } else if q.pop().is_some() {
                popped += 1;
            }
            if q.len() > q.capacity() {
                return Err(format!("len {} > capacity {}", q.len(), q.capacity()));
            }
        }
        let s = q.stats();
        if s.popped != popped {
            return Err(format!("stats.popped {} != {}", s.popped, popped));
        }
        if s.overwrites != overwrites {
            return Err(format!("stats.overwrites {} != {}", s.overwrites, overwrites));
        }
        if s.max_len > q.capacity() {
            return Err(format!("stats.max_len {} > capacity", s.max_len));
        }
        Ok(())
    });
}

/// Invariant #11: fleet runs are exactly reproducible for arbitrary fleet
/// shapes (sessions × batch bound × backpressure × deadline × policy).
#[test]
fn prop_fleet_runs_deterministic() {
    seeded_forall!("fleet_determinism", 4, |rng: &mut Pcg32| {
        let mut sys = SystemConfig::default();
        sys.episode.seed = rng.next_u64();
        sys.fleet.n_sessions = 2 + rng.below(3) as usize;
        sys.fleet.max_batch = 1 + rng.below(4) as usize;
        sys.fleet.max_inflight = 1 + rng.below(6) as usize;
        sys.fleet.batch_deadline_us = rng.below(4) as u64 * 100_000;
        let kinds = [PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::EdgeOnly];
        let kind = kinds[rng.below(3) as usize];
        let run = || rapid::serve::Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let a = run();
        let b = run();
        if a.stats.rounds != b.stats.rounds
            || a.stats.batches != b.stats.batches
            || a.stats.batched_requests != b.stats.batched_requests
            || a.stats.deferred_offloads != b.stats.deferred_offloads
        {
            return Err(format!("scheduler stats differ: {:?} vs {:?}", a.stats, b.stats));
        }
        for (sa, sb) in a.sessions.iter().zip(b.sessions.iter()) {
            for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
                if ma.latency_columns() != mb.latency_columns()
                    || ma.cloud_events != mb.cloud_events
                    || ma.rms_error != mb.rms_error
                {
                    return Err(format!("session {} episodes differ", sa.session));
                }
            }
        }
        Ok(())
    });
}

/// Invariant #12 (link): holding the seed fixed, transfer time is
/// monotone in payload bytes — a same-seed link replays the identical
/// jitter/retransmission stream, so only the bandwidth term can differ.
#[test]
fn prop_link_transfer_monotone_in_bytes() {
    seeded_forall!("link_monotone", 200, |rng: &mut Pcg32| {
        let seed = rng.next_u64();
        let clarity = rng.range(0.05, 1.0);
        let small = rng.range(1e3, 3e6);
        let big = small + rng.range(0.0, 5e6);
        let mut la = Link::new(&LinkConfig::default(), seed);
        let mut lb = Link::new(&LinkConfig::default(), seed);
        let ta = la.transfer(small, clarity);
        let tb = lb.transfer(big, clarity);
        if ta.ms > tb.ms + 1e-12 {
            return Err(format!("{small}B took {}ms > {big}B {}ms", ta.ms, tb.ms));
        }
        if ta.retransmissions != tb.retransmissions {
            return Err("same-seed links diverged on retransmissions".into());
        }
        Ok(())
    });
}

/// Invariant #13 (link): a perfectly clear scene never retransmits, for
/// any payload, seed or retransmission sensitivity.
#[test]
fn prop_link_clarity_one_never_retransmits() {
    seeded_forall!("link_clean", 100, |rng: &mut Pcg32| {
        let mut cfg = LinkConfig::default();
        cfg.noise_retrans = rng.range(0.0, 3.0);
        let mut l = Link::new(&cfg, rng.next_u64());
        for _ in 0..50 {
            let t = l.transfer(rng.range(1e3, 8e6), 1.0);
            if t.retransmissions != 0 {
                return Err(format!("{} retransmissions at clarity 1.0", t.retransmissions));
            }
        }
        Ok(())
    });
}

/// Invariant #14 (link): retransmissions are bounded by 8 even under the
/// worst clarity/sensitivity, and transfer times stay finite and positive.
#[test]
fn prop_link_retransmissions_bounded() {
    seeded_forall!("link_bounded", 100, |rng: &mut Pcg32| {
        let mut cfg = LinkConfig::default();
        cfg.noise_retrans = rng.range(0.5, 4.0); // clamps at p = 0.9
        let mut l = Link::new(&cfg, rng.next_u64());
        for _ in 0..100 {
            let t = l.transfer(rng.range(1e3, 8e6), rng.range(0.0, 0.3));
            if t.retransmissions > 8 {
                return Err(format!("{} retransmissions > 8", t.retransmissions));
            }
            if !(t.ms.is_finite() && t.ms > 0.0) {
                return Err(format!("bad transfer time {}", t.ms));
            }
        }
        Ok(())
    });
}

/// Invariant #15 (link): the lifetime accounting totals equal a naive
/// recomputation over the observed transfers.
#[test]
fn prop_link_totals_account() {
    seeded_forall!("link_totals", 100, |rng: &mut Pcg32| {
        let mut l = Link::new(&LinkConfig::default(), rng.next_u64());
        let mut bytes_naive = 0.0f64;
        let mut retrans_naive = 0u64;
        for _ in 0..60 {
            let bytes = rng.range(1e3, 5e6);
            let t = l.transfer(bytes, rng.range(0.0, 1.0));
            bytes_naive += bytes * (1.0 + t.retransmissions as f64);
            retrans_naive += t.retransmissions as u64;
        }
        if (l.total_bytes - bytes_naive).abs() > 1e-6 {
            return Err(format!("total_bytes {} != naive {bytes_naive}", l.total_bytes));
        }
        if l.total_retrans != retrans_naive {
            return Err(format!("total_retrans {} != naive {retrans_naive}", l.total_retrans));
        }
        Ok(())
    });
}

/// Invariant #16 (cache): the reuse store never exceeds its capacity and
/// its counters reconcile under arbitrary probe/admit interleavings.
#[test]
fn prop_cache_capacity_never_exceeded() {
    use rapid::cache::{ProbeOutcome, ReuseStore, Signature};
    use rapid::config::CacheConfig;
    seeded_forall!("cache_capacity", 100, |rng: &mut Pcg32| {
        let cfg = CacheConfig::default();
        let capacity = 1 + rng.below(16) as usize;
        let ttl = rng.below(64) as u64;
        let shared = rng.chance(0.5);
        let mut store = ReuseStore::new(capacity, ttl, shared, rng.next_u64());
        let mut cloud = rapid::vla::AnalyticBackend::cloud(rng.next_u64());
        let out = rapid::vla::Backend::infer(
            &mut cloud,
            &[0.1; rapid::D_VIS],
            &[0.0; rapid::D_PROP],
            1,
        );
        let mut hits = 0u64;
        let mut misses = 0u64;
        for step in 0..300u64 {
            let f = random_frame(rng, step as usize);
            let sig = Signature::of(&cfg, 1 + rng.below(3) as usize, &f, None, Default::default());
            let owner = rng.below(4) as usize;
            if rng.chance(0.5) {
                store.admit(sig, out.clone(), step, owner);
            } else {
                match store.probe(&sig, step, owner) {
                    ProbeOutcome::Hit(_) => hits += 1,
                    ProbeOutcome::Stale | ProbeOutcome::Miss => misses += 1,
                }
            }
            if store.len() > capacity {
                return Err(format!("len {} > capacity {capacity}", store.len()));
            }
        }
        let s = *store.stats();
        if s.hits != hits || s.misses != misses {
            return Err(format!("stats {s:?} disagree with observed {hits}/{misses}"));
        }
        if s.probes != s.hits + s.misses {
            return Err(format!("probes {} != hits + misses", s.probes));
        }
        if s.stale > s.misses {
            return Err("stale misses exceed misses".into());
        }
        Ok(())
    });
}

/// Invariant #17 (cache): the store replays exactly under a shared seed —
/// identical probe/admit sequences produce identical hit decisions,
/// identical eviction victims and identical counters.
#[test]
fn prop_cache_replay_under_shared_seed() {
    use rapid::cache::{ProbeOutcome, ReuseStore, Signature};
    use rapid::config::CacheConfig;
    seeded_forall!("cache_replay", 50, |rng: &mut Pcg32| {
        let cfg = CacheConfig::default();
        let seed = rng.next_u64();
        let capacity = 1 + rng.below(6) as usize;
        let mut a = ReuseStore::new(capacity, 1000, true, seed);
        let mut b = ReuseStore::new(capacity, 1000, true, seed);
        let mut cloud = rapid::vla::AnalyticBackend::cloud(seed);
        let out = rapid::vla::Backend::infer(
            &mut cloud,
            &[0.1; rapid::D_VIS],
            &[0.0; rapid::D_PROP],
            1,
        );
        for step in 0..200u64 {
            let f = random_frame(rng, step as usize);
            let sig = Signature::of(&cfg, 1, &f, None, Default::default());
            if rng.chance(0.6) {
                a.admit(sig, out.clone(), step, 0);
                b.admit(sig, out.clone(), step, 0);
            } else {
                let ha = matches!(a.probe(&sig, step, 0), ProbeOutcome::Hit(_));
                let hb = matches!(b.probe(&sig, step, 0), ProbeOutcome::Hit(_));
                if ha != hb {
                    return Err(format!("probe diverged at step {step}"));
                }
            }
        }
        if a.stats() != b.stats() {
            return Err(format!("stats diverged: {:?} vs {:?}", a.stats(), b.stats()));
        }
        Ok(())
    });
}

/// Invariant #18 (cache): with `[cache]` absent or `enabled = false` the
/// fleet scheduler is bit-identical to the pre-cache (PR 2) scheduler —
/// the disabled subsystem must not perturb one PRNG draw, one counter or
/// one latency column, for arbitrary fleet shapes and knob values.
#[test]
fn prop_disabled_cache_is_bit_identical() {
    seeded_forall!("cache_disabled_identity", 4, |rng: &mut Pcg32| {
        let mut sys = SystemConfig::default();
        sys.episode.seed = rng.next_u64();
        sys.fleet.n_sessions = 2 + rng.below(3) as usize;
        sys.fleet.max_batch = 1 + rng.below(4) as usize;
        let kinds = [PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::VisionBased];
        let kind = kinds[rng.below(3) as usize];
        let baseline = rapid::serve::Fleet::local(&sys, TaskKind::PickPlace, kind).run();

        // a configured-but-disabled [cache] section with arbitrary knobs
        let mut cached = sys.clone();
        cached.cache.enabled = false;
        cached.cache.capacity = 1 + rng.below(512) as usize;
        cached.cache.ttl_rounds = rng.below(1000) as u64;
        cached.cache.seed = rng.next_u64();
        cached.cache.quant = rng.range(0.001, 1.0);
        cached.cache.shared = rng.chance(0.5);
        let run = rapid::serve::Fleet::local(&cached, TaskKind::PickPlace, kind).run();

        if baseline.stats.rounds != run.stats.rounds
            || baseline.stats.batched_requests != run.stats.batched_requests
        {
            return Err(format!("scheduler stats differ: {:?} vs {:?}", baseline.stats, run.stats));
        }
        if !run.cache.is_zero() {
            return Err(format!("disabled cache recorded activity: {:?}", run.cache));
        }
        for (sa, sb) in baseline.sessions.iter().zip(run.sessions.iter()) {
            for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
                if ma.latency_columns() != mb.latency_columns()
                    || ma.cloud_events != mb.cloud_events
                    || ma.rms_error != mb.rms_error
                    || ma.cache_hits != 0
                    || mb.cache_hits != 0
                {
                    return Err(format!("session {} diverged with cache disabled", sa.session));
                }
            }
        }
        Ok(())
    });
}

/// Invariant #19 (zoo): no flushed batch ever mixes model families, for
/// random fleet shapes, family subsets, deadlines and policies — the
/// arrival interleavings the family seal must survive. Family totals must
/// also exactly partition the fleet totals.
#[test]
fn prop_zoo_batches_never_mix_families() {
    seeded_forall!("zoo_no_mixing", 6, |rng: &mut Pcg32| {
        let mut sys = SystemConfig::default();
        sys.episode.seed = rng.next_u64();
        sys.fleet.n_sessions = 3 + rng.below(6) as usize;
        sys.fleet.max_batch = 1 + rng.below(5) as usize;
        sys.fleet.batch_deadline_us = rng.below(3) as u64 * 100_000;
        sys.models.enabled = true;
        let all = ["surrogate", "openvla", "pi0", "edgequant"];
        let n_fams = 2 + rng.below(3) as usize;
        let start = rng.below(4) as usize;
        let picked: Vec<&str> = (0..n_fams).map(|k| all[(start + k) % 4]).collect();
        sys.models.families = picked.join(",");
        let kinds = [PolicyKind::Rapid, PolicyKind::CloudOnly];
        let kind = kinds[rng.below(2) as usize];
        let res = rapid::serve::Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        if res.stats.mixed_family_batches != 0 {
            return Err(format!("{} mixed batches", res.stats.mixed_family_batches));
        }
        let steps: u64 = res.families.iter().map(|t| t.steps).sum();
        let cloud: u64 = res.families.iter().map(|t| t.cloud_events).sum();
        let batches: u64 = res.families.iter().map(|t| t.batches).sum();
        let reqs: u64 = res.families.iter().map(|t| t.batched_requests).sum();
        if steps != res.total_steps() || cloud != res.total_cloud_events() {
            return Err("family totals don't partition session totals".into());
        }
        if batches != res.stats.batches || reqs != res.stats.batched_requests {
            return Err("family batch counters don't partition scheduler totals".into());
        }
        for s in &res.sessions {
            for m in &s.episodes {
                if m.steps != TaskKind::PickPlace.seq_len() {
                    return Err(format!("session {} wedged ({:?})", s.session, s.family));
                }
            }
        }
        Ok(())
    });
}

/// Invariant #20 (zoo): the planner's partition choice is monotone in
/// link bandwidth under a shared seed — more bandwidth never shrinks the
/// chosen payload (ties break toward the shallower split), and the
/// chosen cost never increases with bandwidth.
#[test]
fn prop_planner_monotone_in_bandwidth() {
    use rapid::policy::planner;
    use rapid::vla::profile::{FamilyProfile, ModelFamily};
    seeded_forall!("planner_monotone", 300, |rng: &mut Pcg32| {
        let fam = ModelFamily::ALL[rng.below(4) as usize];
        let prof = FamilyProfile::of(fam);
        let rtt = rng.range(1.0, 120.0);
        let bw_lo = rng.range(1.0, 800.0);
        let bw_hi = bw_lo + rng.range(0.0, 2000.0);
        let lo = planner::plan(&prof, bw_lo, rtt);
        let hi = planner::plan(&prof, bw_hi, rtt);
        if hi.payload_bytes + 1e-9 < lo.payload_bytes {
            return Err(format!(
                "{fam:?}: payload shrank {} -> {} as bw rose {bw_lo} -> {bw_hi}",
                lo.payload_bytes, hi.payload_bytes
            ));
        }
        let cost = |p: &rapid::policy::FamilyPlan, bw: f64| {
            planner::partition_cost(&prof.partitions[p.partition_idx], bw, rtt)
        };
        if cost(&hi, bw_hi) > cost(&lo, bw_lo) + 1e-9 {
            return Err(format!("{fam:?}: chosen cost rose with bandwidth"));
        }
        // determinism under the shared inputs
        if planner::plan(&prof, bw_lo, rtt) != lo {
            return Err("planner non-deterministic".into());
        }
        Ok(())
    });
}

/// Invariant #21 (zoo/cache): family-tagged signatures never serve a hit
/// across families — a state admitted under exactly one family hits for
/// that family alone, under arbitrary admission interleavings.
#[test]
fn prop_family_signatures_never_cross_serve() {
    use rapid::cache::{ProbeOutcome, ReuseStore, Signature};
    use rapid::config::CacheConfig;
    use rapid::vla::profile::ModelFamily;
    seeded_forall!("family_no_cross_serve", 60, |rng: &mut Pcg32| {
        let cfg = CacheConfig::default();
        let mut store = ReuseStore::new(64, 10_000, true, rng.next_u64());
        let mut cloud = rapid::vla::AnalyticBackend::cloud(rng.next_u64());
        let out = rapid::vla::Backend::infer(
            &mut cloud,
            &[0.1; rapid::D_VIS],
            &[0.0; rapid::D_PROP],
            1,
        );
        // distinct states, each admitted under exactly one random family
        let mut admitted: Vec<(SensorFrame, ModelFamily)> = Vec::new();
        for step in 0..40u64 {
            let f = random_frame(rng, step as usize);
            let fam = ModelFamily::ALL[rng.below(4) as usize];
            let sig = Signature::of(&cfg, 1, &f, None, fam);
            store.admit(sig, out.clone(), step, rng.below(4) as usize);
            admitted.push((f, fam));
        }
        for (f, fam) in &admitted {
            for probe_fam in ModelFamily::ALL {
                let sig = Signature::of(&cfg, 1, f, None, probe_fam);
                let hit = matches!(store.probe(&sig, 50, 0), ProbeOutcome::Hit(_));
                let admitted_under_probe_fam = admitted
                    .iter()
                    .any(|(g, gf)| *gf == probe_fam && frames_bin_equal(&cfg, g, f));
                if hit && !admitted_under_probe_fam {
                    return Err(format!(
                        "{probe_fam:?} hit a chunk admitted under {fam:?}"
                    ));
                }
                if !hit && admitted_under_probe_fam {
                    return Err(format!(
                        "{probe_fam:?} missed its own admitted state (capacity untouched)"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Two frames quantize into the same signature bins (used by the
/// cross-serve property to discount genuine same-state collisions).
fn frames_bin_equal(cfg: &rapid::config::CacheConfig, a: &SensorFrame, b: &SensorFrame) -> bool {
    use rapid::cache::Signature;
    Signature::of(cfg, 1, a, None, Default::default())
        == Signature::of(cfg, 1, b, None, Default::default())
}

/// Invariant #22 (events): the fleet event queue pops every random event
/// set in one deterministic, time-monotone order — times never decrease,
/// within a time classes order `FaultEdge < Arrival < Ready < Deadline`,
/// within a class session indices ascend, and exact duplicates pop FIFO
/// (the `(time, class, seq, push order)` contract the lockstep
/// bit-identity rests on).
#[test]
fn prop_event_queue_pop_order_deterministic_and_monotone() {
    use rapid::serve::{EventKind, EventQueue};
    seeded_forall!("event_queue_order", 120, |rng: &mut Pcg32| {
        let n = 1 + rng.below(200) as usize;
        let pushes: Vec<(u64, EventKind)> = (0..n)
            .map(|_| {
                let t = rng.below(50) as u64;
                let kind = match rng.below(4) {
                    0 => EventKind::FaultEdge,
                    1 => EventKind::Arrival(rng.below(16) as usize),
                    2 => EventKind::Ready(rng.below(16) as usize),
                    _ => EventKind::Deadline,
                };
                (t, kind)
            })
            .collect();
        let drain = |pushes: &[(u64, EventKind)]| {
            let mut q = EventQueue::new();
            for &(t, k) in pushes {
                q.push(t, k);
            }
            let mut popped = Vec::new();
            while let Some(ev) = q.pop() {
                popped.push(ev);
            }
            popped
        };
        let a = drain(&pushes);
        let b = drain(&pushes);
        if a.len() != n || b.len() != n {
            return Err(format!("lost events: {} / {} of {n}", a.len(), b.len()));
        }
        for (ea, eb) in a.iter().zip(b.iter()) {
            if ea.key() != eb.key() {
                return Err("identical push sequences popped differently".into());
            }
        }
        for w in a.windows(2) {
            if w[1].key() <= w[0].key() {
                return Err(format!(
                    "pop order not strictly increasing: {:?} then {:?}",
                    w[0].key(),
                    w[1].key()
                ));
            }
            if w[1].time < w[0].time {
                return Err("queue went back in time".into());
            }
        }
        Ok(())
    });
}

/// Invariant #23 (workload): under random arrival shapes, episode-count
/// draws and family mixes, the fleet's totals exactly partition across
/// sessions and families, every arrival is accounted, no batch mixes
/// families, and no session wedges — the conservation laws survive open-
/// loop dynamics.
#[test]
fn prop_fleet_totals_partition_under_random_arrivals() {
    seeded_forall!("workload_partition", 5, |rng: &mut Pcg32| {
        let mut sys = SystemConfig::default();
        sys.episode.seed = rng.next_u64();
        sys.fleet.n_sessions = 2 + rng.below(5) as usize;
        sys.fleet.max_batch = 1 + rng.below(4) as usize;
        sys.workload.enabled = true;
        sys.workload.seed = rng.next_u64();
        sys.workload.arrivals =
            ["fixed", "poisson", "bursty"][rng.below(3) as usize].to_string();
        sys.workload.interarrival_rounds = rng.range(0.0, 12.0);
        sys.workload.burst_len = 1 + rng.below(4) as u64;
        sys.workload.idle_len = rng.below(10) as u64;
        sys.workload.episodes_min = 1;
        sys.workload.episodes_max = 1 + rng.below(2) as usize;
        if rng.chance(0.5) {
            sys.models.enabled = true;
            sys.workload.family_mix =
                if rng.chance(0.5) { "draw".into() } else { "blocks".into() };
        }
        let kinds = [PolicyKind::Rapid, PolicyKind::CloudOnly];
        let kind = kinds[rng.below(2) as usize];
        let res = rapid::serve::Fleet::local(&sys, TaskKind::PickPlace, kind).run();

        if res.stats.arrivals != res.sessions.len() as u64 {
            return Err(format!(
                "{} arrivals for {} sessions",
                res.stats.arrivals,
                res.sessions.len()
            ));
        }
        if res.stats.mixed_family_batches != 0 {
            return Err(format!("{} mixed batches", res.stats.mixed_family_batches));
        }
        // per-session episodes complete and sum to the fleet totals
        let mut steps = 0u64;
        let mut cloud = 0u64;
        for s in &res.sessions {
            if s.episodes.is_empty() {
                return Err(format!("session {} completed no episodes", s.session));
            }
            if s.departure_round < s.arrival_round {
                return Err(format!("session {} departed before arriving", s.session));
            }
            for m in &s.episodes {
                if m.steps != TaskKind::PickPlace.seq_len() {
                    return Err(format!("session {} wedged", s.session));
                }
                steps += m.steps as u64;
                cloud += m.cloud_events;
            }
        }
        if steps != res.total_steps() || cloud != res.total_cloud_events() {
            return Err("session sums don't match fleet totals".into());
        }
        // family rows partition the same totals
        let fsteps: u64 = res.families.iter().map(|t| t.steps).sum();
        let fcloud: u64 = res.families.iter().map(|t| t.cloud_events).sum();
        let freqs: u64 = res.families.iter().map(|t| t.batched_requests).sum();
        if fsteps != steps || fcloud != cloud || freqs != res.stats.batched_requests {
            return Err("family totals don't partition fleet totals".into());
        }
        // every wire request came from a session offload (no cache here)
        if res.stats.batched_requests != cloud {
            return Err(format!(
                "batched {} != cloud events {cloud}",
                res.stats.batched_requests
            ));
        }
        Ok(())
    });
}

/// Invariant #24 (workload): with `[workload]` absent or `enabled =
/// false` — whatever the other workload knobs say — the fleet scheduler
/// is bit-identical to the pre-workload (PR 4) scheduler: same rounds,
/// same batches, same per-episode trajectories, for arbitrary fleet
/// shapes and hostile knob values.
#[test]
fn prop_disabled_workload_is_bit_identical() {
    seeded_forall!("workload_disabled_identity", 4, |rng: &mut Pcg32| {
        let mut sys = SystemConfig::default();
        sys.episode.seed = rng.next_u64();
        sys.fleet.n_sessions = 2 + rng.below(3) as usize;
        sys.fleet.max_batch = 1 + rng.below(4) as usize;
        let kinds = [PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::VisionBased];
        let kind = kinds[rng.below(3) as usize];
        let baseline = rapid::serve::Fleet::local(&sys, TaskKind::PickPlace, kind).run();

        // a configured-but-disabled [workload] section with hostile knobs
        let mut loaded = sys.clone();
        loaded.workload.enabled = false;
        loaded.workload.arrivals =
            ["poisson", "bursty", "trace", "garbage"][rng.below(4) as usize].to_string();
        loaded.workload.n_sessions = rng.below(64) as usize;
        loaded.workload.start_round = rng.below(1000) as u64;
        loaded.workload.interarrival_rounds = rng.range(0.0, 50.0);
        loaded.workload.seed = rng.next_u64();
        loaded.workload.episodes_min = rng.below(5) as usize;
        loaded.workload.episodes_max = rng.below(9) as usize;
        loaded.workload.family_mix = "draw".into();
        loaded.workload.trace = "9999, 123, junk".into();
        let run = rapid::serve::Fleet::local(&loaded, TaskKind::PickPlace, kind).run();

        if baseline.stats.rounds != run.stats.rounds
            || baseline.stats.batches != run.stats.batches
            || baseline.stats.batched_requests != run.stats.batched_requests
            || baseline.stats.arrivals != run.stats.arrivals
        {
            return Err(format!("scheduler stats differ: {:?} vs {:?}", baseline.stats, run.stats));
        }
        for (sa, sb) in baseline.sessions.iter().zip(run.sessions.iter()) {
            if sb.arrival_round != 0 {
                return Err(format!("session {} arrived late with workload off", sb.session));
            }
            if sa.departure_round != sb.departure_round {
                return Err(format!("session {} departure drifted", sa.session));
            }
            for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
                if ma.latency_columns() != mb.latency_columns()
                    || ma.cloud_events != mb.cloud_events
                    || ma.rms_error != mb.rms_error
                {
                    return Err(format!("session {} diverged with workload disabled", sa.session));
                }
            }
        }
        Ok(())
    });
}

/// Invariant #25 (cache): the sharded reuse store is observably
/// equivalent to the historical single-map store. For random
/// probe/admit interleavings with capacity above the working set (so no
/// shard can evict and no RNG is drawn), every probe outcome and every
/// counter must match the single-map store exactly, for any shard
/// count. Under eviction pressure, the total resident count must stay
/// within the configured capacity and the admission/refresh/eviction
/// counters must reconcile with the resident count.
#[test]
fn prop_sharded_store_equivalent_and_bounded() {
    use rapid::cache::{ProbeOutcome, ReuseStore, Signature};
    use rapid::config::CacheConfig;

    seeded_forall!("sharded_store", 40, |rng: &mut Pcg32| {
        let cfg = CacheConfig { enabled: true, ..Default::default() };
        let seed = rng.next_u64();
        let shards = 1usize << rng.below(4); // 1, 2, 4, or 8
        // a small discrete signature space so probes repeatedly land on
        // admitted keys (and spread across shards when sharded)
        let sigs: Vec<Signature> = (0..24u32)
            .map(|i| {
                let frame = SensorFrame {
                    step: 0,
                    q: Jv::splat(0.5 * i as f64),
                    dq: Jv::ZERO,
                    tau: Jv::ZERO,
                };
                Signature::of(&cfg, (i % 4) as usize, &frame, None, Default::default())
            })
            .collect();
        let chunk = {
            let mut cloud = rapid::vla::AnalyticBackend::cloud(1);
            rapid::vla::Backend::infer(
                &mut cloud,
                &[0.1; rapid::D_VIS],
                &[0.0; rapid::D_PROP],
                1,
            )
        };

        // equivalence half: capacity far above the admission count, so
        // no shard can evict and the stores must agree outcome-for-outcome
        let mut a = ReuseStore::new(512, 64, true, seed);
        let mut b = ReuseStore::with_shards(512, 64, true, seed, shards);
        for round in 0..200u64 {
            let sig = sigs[rng.below(24) as usize];
            let owner = rng.below(3) as usize;
            if rng.chance(0.5) {
                let oa = a.probe(&sig, round, owner);
                let ob = b.probe(&sig, round, owner);
                let same = matches!(
                    (&oa, &ob),
                    (ProbeOutcome::Hit(_), ProbeOutcome::Hit(_))
                        | (ProbeOutcome::Stale, ProbeOutcome::Stale)
                        | (ProbeOutcome::Miss, ProbeOutcome::Miss)
                );
                if !same {
                    return Err(format!(
                        "probe outcomes diverged at round {round} ({shards} shards)"
                    ));
                }
            } else {
                a.admit(sig, chunk.clone(), round, owner);
                b.admit(sig, chunk.clone(), round, owner);
            }
        }
        if a.stats() != b.stats() {
            return Err(format!("stats diverged: {:?} vs {:?}", a.stats(), b.stats()));
        }
        if a.len() != b.len() {
            return Err(format!("resident counts diverged: {} vs {}", a.len(), b.len()));
        }

        // pressure half: tiny capacity, many admits — the total capacity
        // bound and counter reconciliation must hold for any shard spread
        let cap = 1 + rng.below(16) as usize;
        let mut c = ReuseStore::with_shards(cap, 64, rng.chance(0.5), seed, shards);
        for round in 0..300u64 {
            let sig = sigs[rng.below(24) as usize];
            c.admit(sig, chunk.clone(), round, rng.below(4) as usize);
            if c.len() > cap {
                return Err(format!("resident {} > capacity {cap}", c.len()));
            }
        }
        let st = *c.stats();
        if st.admissions - st.refreshed - st.evictions != c.len() as u64 {
            return Err(format!("counters do not reconcile: {st:?} vs len {}", c.len()));
        }
        Ok(())
    });
}

/// Cooldown unit property: ready exactly after `limit` ticks.
#[test]
fn prop_cooldown_exact() {
    seeded_forall!("cooldown_exact", 100, |rng: &mut Pcg32| {
        let limit = rng.below(64);
        let mut cd = Cooldown::new(limit);
        cd.arm();
        let mut ticks = 0;
        while !cd.ready() {
            cd.tick();
            ticks += 1;
            if ticks > limit + 1 {
                return Err(format!("never ready, limit {limit}"));
            }
        }
        if ticks != limit {
            return Err(format!("ready after {ticks}, limit {limit}"));
        }
        Ok(())
    });
}

/// Invariant #26 (cache): the sharded store's TTL clock. `next_round()`
/// is a monotone high-water mark over admissions (probes never move it),
/// and TTL expiry is shard-invariant: with capacity above the working
/// set (no evictions, so the store draws no RNG), stores at shard counts
/// {1, 4, 16} must agree on every probe outcome — hits, misses, and
/// TTL-stale discoveries — on `next_round()`, and on every counter,
/// under random interleavings of admit / probe / clock advances that
/// jump past the TTL.
#[test]
fn prop_sharded_ttl_clock_monotone_and_shard_invariant() {
    use rapid::cache::{ProbeOutcome, ReuseStore, Signature};
    use rapid::config::CacheConfig;

    seeded_forall!("sharded_ttl_clock", 40, |rng: &mut Pcg32| {
        let cfg = CacheConfig { enabled: true, ..Default::default() };
        let seed = rng.next_u64();
        let ttl = 1 + rng.below(12) as u64;
        let sigs: Vec<Signature> = (0..24u32)
            .map(|i| {
                let frame = SensorFrame {
                    step: 0,
                    q: Jv::splat(0.5 * i as f64),
                    dq: Jv::ZERO,
                    tau: Jv::ZERO,
                };
                Signature::of(&cfg, (i % 4) as usize, &frame, None, Default::default())
            })
            .collect();
        let chunk = {
            let mut cloud = rapid::vla::AnalyticBackend::cloud(1);
            rapid::vla::Backend::infer(&mut cloud, &[0.1; rapid::D_VIS], &[0.0; rapid::D_PROP], 1)
        };

        let mut stores: Vec<ReuseStore> = [1usize, 4, 16]
            .iter()
            .map(|&s| ReuseStore::with_shards(512, ttl, true, seed, s))
            .collect();
        let mut round = 0u64;
        let mut hw = 0u64; // the expected next_round() high-water mark
        for op in 0..250u32 {
            // the scheduler clock only moves forward — sometimes far
            // enough past the TTL to age out everything admitted so far
            if rng.chance(0.3) {
                round += rng.below(2 * ttl as u32 + 2) as u64;
            }
            let sig = sigs[rng.below(24) as usize];
            let owner = rng.below(3) as usize;
            if rng.chance(0.5) {
                let o0 = stores[0].probe(&sig, round, owner);
                for s in stores[1..].iter_mut() {
                    let o = s.probe(&sig, round, owner);
                    let same = matches!(
                        (&o0, &o),
                        (ProbeOutcome::Hit(_), ProbeOutcome::Hit(_))
                            | (ProbeOutcome::Stale, ProbeOutcome::Stale)
                            | (ProbeOutcome::Miss, ProbeOutcome::Miss)
                    );
                    if !same {
                        return Err(format!(
                            "probe outcomes diverged at op {op}, round {round} (ttl {ttl})"
                        ));
                    }
                }
            } else {
                for s in stores.iter_mut() {
                    s.admit(sig, chunk.clone(), round, owner);
                }
                hw = hw.max(round.saturating_add(1));
            }
            // `hw` never decreases by construction, so agreement with it
            // on every store pins both monotonicity and shard-invariance
            for s in &stores {
                if s.next_round() != hw {
                    return Err(format!(
                        "next_round drifted at op {op}: {} vs expected {hw} ({} shards)",
                        s.next_round(),
                        s.n_shards()
                    ));
                }
            }
        }
        let st0 = *stores[0].stats();
        for s in &stores[1..] {
            if *s.stats() != st0 {
                return Err(format!(
                    "TTL counters diverged: {:?} vs {:?} ({} shards)",
                    st0,
                    s.stats(),
                    s.n_shards()
                ));
            }
        }
        Ok(())
    });
}

/// Invariant #27 (pipeline): with `[pipeline]` absent, disabled —
/// whatever the other knobs say — or enabled with both stages off, the
/// fleet scheduler is bit-identical to the sequential scheduler: same
/// rounds, same batches, zero speculative requests, same per-episode
/// trajectories, for arbitrary fleet shapes and hostile knob values.
#[test]
fn prop_disabled_pipeline_is_bit_identical() {
    seeded_forall!("pipeline_disabled_identity", 4, |rng: &mut Pcg32| {
        let mut sys = SystemConfig::default();
        sys.episode.seed = rng.next_u64();
        sys.fleet.n_sessions = 2 + rng.below(3) as usize;
        sys.fleet.max_batch = 1 + rng.below(4) as usize;
        let kinds = [PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::VisionBased];
        let kind = kinds[rng.below(3) as usize];
        let baseline = rapid::serve::Fleet::local(&sys, TaskKind::PickPlace, kind).run();

        // a configured-but-inert [pipeline] section with hostile knobs:
        // half the cases disabled outright, half enabled-but-degenerate
        let mut loaded = sys.clone();
        loaded.pipeline.enabled = rng.chance(0.5);
        loaded.pipeline.overlap = false;
        loaded.pipeline.speculate = false;
        if !loaded.pipeline.enabled {
            // stages armed but the master switch off
            loaded.pipeline.overlap = rng.chance(0.5);
            loaded.pipeline.speculate = rng.chance(0.5);
        }
        loaded.pipeline.spec_decode_ms = rng.range(0.0, 500.0);
        loaded.pipeline.rollback_ms = rng.range(0.0, 500.0);
        loaded.pipeline.accept_eps = rng.range(0.0, 1.0);
        loaded.pipeline.max_zscore = rng.range(-2.0, 10.0);
        let run = rapid::serve::Fleet::local(&loaded, TaskKind::PickPlace, kind).run();

        if baseline.stats.rounds != run.stats.rounds
            || baseline.stats.batches != run.stats.batches
            || baseline.stats.batched_requests != run.stats.batched_requests
            || run.stats.spec_requests != 0
        {
            return Err(format!("scheduler stats differ: {:?} vs {:?}", baseline.stats, run.stats));
        }
        for (sa, sb) in baseline.sessions.iter().zip(run.sessions.iter()) {
            for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
                if ma.latency_columns() != mb.latency_columns()
                    || ma.cloud_events != mb.cloud_events
                    || ma.rms_error != mb.rms_error
                    || mb.spec_dispatches != 0
                    || mb.overlap_hidden_ms != 0.0
                {
                    return Err(format!("session {} diverged with pipeline inert", sa.session));
                }
            }
        }
        Ok(())
    });
}

/// Invariant #28 (obs): histogram quantiles are monotone in `p`, bounded
/// by the observed max, and the bucket map is monotone in the sample —
/// for arbitrary sample streams including zeros, negatives (clamped) and
/// huge outliers.
#[test]
fn prop_histogram_quantiles_monotone() {
    use rapid::obs::hist::{bucket_index, LogHistogram};
    seeded_forall!("hist_monotone", 200, |rng: &mut Pcg32| {
        let mut h = LogHistogram::new();
        let n = 1 + rng.below(400) as usize;
        let mut top = 0.0f64;
        for _ in 0..n {
            let v = match rng.below(8) {
                0 => 0.0,
                1 => -rng.range(0.0, 100.0), // clamps to bucket 0
                2 => rng.range(1e9, 1e15),
                _ => rng.range(0.0, 1e6),
            };
            h.insert(v);
            top = top.max(v);
        }
        if h.count() != n as u64 {
            return Err(format!("count {} != {n}", h.count()));
        }
        let mut prev = -1.0;
        for i in 0..=20 {
            let q = h.quantile(i as f64 / 20.0);
            if q < prev {
                return Err(format!("quantile not monotone at p={}: {q} < {prev}", i as f64 / 20.0));
            }
            if q > h.max() {
                return Err(format!("quantile {q} exceeds max {}", h.max()));
            }
            prev = q;
        }
        if (h.max() - top).abs() > 0.0 {
            return Err(format!("max {} != observed {top}", h.max()));
        }
        // bucket map is monotone: a larger sample never lands lower
        let (a, b) = (rng.range(0.0, 1e9), rng.range(0.0, 1e9));
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        if bucket_index(lo) > bucket_index(hi) {
            return Err(format!("bucket_index not monotone: {lo} -> {hi}"));
        }
        Ok(())
    });
}

/// Invariant #29 (obs): histogram merge is *exactly* associative and
/// commutative — per-shard histograms folded in any order produce
/// bit-identical registries (no float sum anywhere in the fold).
#[test]
fn prop_histogram_merge_associative() {
    use rapid::obs::LogHistogram;
    seeded_forall!("hist_merge_assoc", 200, |rng: &mut Pcg32| {
        let mut parts = [LogHistogram::new(), LogHistogram::new(), LogHistogram::new()];
        for h in parts.iter_mut() {
            for _ in 0..rng.below(64) {
                h.insert(rng.range(0.0, 1e7));
            }
        }
        let [a, b, c] = &parts;
        let mut ab_c = a.clone();
        ab_c.merge(b);
        ab_c.merge(c);
        let mut bc = b.clone();
        bc.merge(c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        if ab_c != a_bc {
            return Err("merge is not associative".to_string());
        }
        let mut ba = b.clone();
        ba.merge(a);
        let mut ab = a.clone();
        ab.merge(b);
        if ab != ba {
            return Err("merge is not commutative".to_string());
        }
        if ab_c.count() != a.count() + b.count() + c.count() {
            return Err("merged count is not the sum".to_string());
        }
        Ok(())
    });
}

/// Invariant #30 (obs): arming `[trace]` — including hostile knob values
/// like a 1-span cap that drops nearly everything — never perturbs the
/// scheduler: a traced fleet is bit-identical to the untraced one for
/// arbitrary fleet shapes, policies, and cache/fault toggles.
#[test]
fn prop_traced_fleet_is_bit_identical() {
    seeded_forall!("trace_identity", 4, |rng: &mut Pcg32| {
        let mut sys = SystemConfig::default();
        sys.episode.seed = rng.next_u64();
        sys.fleet.n_sessions = 2 + rng.below(3) as usize;
        sys.fleet.max_batch = 1 + rng.below(4) as usize;
        sys.cache.enabled = rng.chance(0.5);
        if rng.chance(0.3) {
            sys.fleet.endpoints = 2;
            sys.faults = rapid::config::FaultsConfig::demo();
        }
        let kinds = [PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::VisionBased];
        let kind = kinds[rng.below(3) as usize];
        let baseline = rapid::serve::Fleet::local(&sys, TaskKind::PickPlace, kind).run();

        let mut traced = sys.clone();
        traced.trace.enabled = true;
        traced.trace.max_spans = if rng.chance(0.5) { 1 } else { 1 << 16 };
        traced.trace.flight_events = rng.below(8) as usize;
        let run = rapid::serve::Fleet::local(&traced, TaskKind::PickPlace, kind).run();

        if baseline.stats.rounds != run.stats.rounds
            || baseline.stats.batches != run.stats.batches
            || baseline.stats.batched_requests != run.stats.batched_requests
            || baseline.stats.dropped_replies != run.stats.dropped_replies
            || baseline.stats.degraded_requests != run.stats.degraded_requests
            || baseline.endpoint_dispatches != run.endpoint_dispatches
            || baseline.cache.hits != run.cache.hits
        {
            return Err(format!("scheduler stats differ: {:?} vs {:?}", baseline.stats, run.stats));
        }
        if run.trace.is_none() {
            return Err("enabled trace was not harvested".to_string());
        }
        for (sa, sb) in baseline.sessions.iter().zip(run.sessions.iter()) {
            for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
                if ma.latency_columns() != mb.latency_columns()
                    || ma.cloud_events != mb.cloud_events
                    || ma.failovers != mb.failovers
                    || ma.cache_hits != mb.cache_hits
                    || ma.rms_error != mb.rms_error
                {
                    return Err(format!("session {} diverged under tracing", sa.session));
                }
            }
        }
        Ok(())
    });
}

/// Invariant #31 (placement): the multi-factor planner reduces to the
/// single-factor one when every new factor is neutral. With an unlimited
/// device budget and a nominal endpoint — and equally with any queue
/// depth under a zero queue weight at unit capacity, since the load
/// multiplier is then exactly 1.0 — `plan_with` must return the
/// bit-identical plan `plan` returns, for arbitrary families and links.
#[test]
fn prop_multi_factor_planner_reduces_to_single_factor() {
    use rapid::policy::planner;
    use rapid::vla::profile::{FamilyProfile, ModelFamily};
    seeded_forall!("placement_reduction", 300, |rng: &mut Pcg32| {
        let fam = ModelFamily::ALL[rng.below(4) as usize];
        let prof = FamilyProfile::of(fam);
        let bw = rng.range(0.5, 2000.0);
        let rtt = rng.range(0.5, 150.0);
        let base = planner::plan(&prof, bw, rtt);
        let unlimited = planner::plan_with(
            &prof,
            bw,
            rtt,
            planner::DeviceBudget::UNLIMITED,
            planner::EndpointLoad::NOMINAL,
        );
        if unlimited != base {
            return Err(format!("{fam:?}: UNLIMITED/NOMINAL diverged: {unlimited:?} vs {base:?}"));
        }
        // a deep queue behind a zero weight still multiplies by exactly 1.0
        let loaded = planner::EndpointLoad {
            queue_depth: rng.below(64) as u64,
            capacity: 1.0,
            queue_weight: 0.0,
        };
        let neutral =
            planner::plan_with(&prof, bw, rtt, planner::DeviceBudget::UNLIMITED, loaded);
        if neutral != base {
            return Err(format!("{fam:?}: zero-weight load perturbed the plan"));
        }
        Ok(())
    });
}

/// Invariant #32 (placement): the budget filter is sound and complete.
/// For random catalogs, budgets, and endpoint loads, a non-edge-only
/// plan's chosen split always fits the device budget, and the planner
/// degrades to the edge-only sentinel exactly when *no* split fits —
/// never because of endpoint load, which reweights but cannot filter.
#[test]
fn prop_budget_filter_is_sound_and_complete() {
    use rapid::policy::planner;
    use rapid::vla::profile::{FamilyProfile, ModelFamily};
    seeded_forall!("placement_budget", 300, |rng: &mut Pcg32| {
        let fam = ModelFamily::ALL[rng.below(4) as usize];
        let prof = FamilyProfile::of(fam);
        let bw = rng.range(0.5, 2000.0);
        let rtt = rng.range(0.5, 150.0);
        let budget = planner::DeviceBudget {
            mem_gb: rng.range(0.1, 9.0),
            prefix_ms: rng.range(0.5, 90.0),
        };
        let load = planner::EndpointLoad {
            queue_depth: rng.below(32) as u64,
            capacity: rng.range(0.1, 4.0),
            queue_weight: rng.range(0.0, 2.0),
        };
        let p = planner::plan_with(&prof, bw, rtt, budget, load);
        let any_fits = prof.partitions.iter().any(|pt| budget.admits(pt));
        if p.is_edge_only() {
            if any_fits {
                return Err(format!(
                    "{fam:?}: degraded to edge-only with admissible splits ({budget:?})"
                ));
            }
            return Ok(());
        }
        let chosen = &prof.partitions[p.partition_idx];
        if chosen.edge_gb > budget.mem_gb {
            return Err(format!(
                "{fam:?}: chose edge_gb {} over budget {}",
                chosen.edge_gb, budget.mem_gb
            ));
        }
        if chosen.edge_prefix_ms > budget.prefix_ms {
            return Err(format!(
                "{fam:?}: chose prefix {} ms over budget {} ms",
                chosen.edge_prefix_ms, budget.prefix_ms
            ));
        }
        if !any_fits {
            return Err(format!("{fam:?}: offloading plan with an empty admissible set"));
        }
        Ok(())
    });
}

//! Differential conformance suite for the device-heterogeneity zoo
//! (`[devices] classes` + `[workload] device_mix`).
//!
//! Three halves:
//!
//! * **Disabled ⇒ bit-identity.** A `[devices]` section left disabled —
//!   whatever the other knobs say, however hostile — must leave the
//!   scheduler *exactly* the PR 9 event loop: per-episode trajectories,
//!   flush causes, cache counters and fault-engine draws, across every
//!   serve path (plain fleets, the reuse cache, the chaos schedule, the
//!   model zoo, pipelined execution, dynamic arrivals).
//! * **Default-class-only ⇒ bit-identity.** `classes = "cloudlet"`
//!   *enabled* must also perturb nothing: every cloudlet factor is an
//!   exact no-op (`x * 1.0 == x`, grid off, unlimited budget), so the
//!   armed code path itself is proven inert before any real class runs.
//! * **Enabled holds the line.** A mixed lite/nx/agx fleet completes
//!   under chaos, replays bit-identically, rolls totals up by class
//!   (exactly partitioning the fleet totals), plans provably different
//!   partition points per class (edge-only on lite), and never serves a
//!   cache hit across a class boundary. Unknown class names and bad
//!   `device_mix` values are config-load errors, never silent defaults.

use rapid::config::{FaultsConfig, PolicyKind, SystemConfig};
use rapid::robot::TaskKind;
use rapid::runtime::DeviceClass;
use rapid::serve::{Fleet, FleetResult};
use rapid::vla::ModelFamily;

/// Full-strength bit-identity: scheduler counters, flush causes, router
/// spread, cache counters, and exact per-episode trajectory columns.
fn assert_bit_identical(a: &FleetResult, b: &FleetResult, tag: &str) {
    assert_eq!(a.stats.rounds, b.stats.rounds, "{tag}: rounds");
    assert_eq!(a.stats.batches, b.stats.batches, "{tag}: batches");
    assert_eq!(a.stats.batched_requests, b.stats.batched_requests, "{tag}: batched requests");
    assert_eq!(
        a.stats.multi_session_batches, b.stats.multi_session_batches,
        "{tag}: multi-session batches"
    );
    assert_eq!(a.stats.max_batch_observed, b.stats.max_batch_observed, "{tag}: batch high-water");
    assert_eq!(
        a.stats.max_inflight_observed, b.stats.max_inflight_observed,
        "{tag}: inflight high-water"
    );
    assert_eq!(a.stats.endpoint_errors, b.stats.endpoint_errors, "{tag}: endpoint errors");
    assert_eq!(a.stats.mixed_family_batches, b.stats.mixed_family_batches, "{tag}: mixed batches");
    assert_eq!(a.stats.spec_requests, b.stats.spec_requests, "{tag}: speculative requests");
    assert_eq!(a.stats.arrivals, b.stats.arrivals, "{tag}: arrivals");
    assert_eq!(
        a.stats.max_active_sessions, b.stats.max_active_sessions,
        "{tag}: active-session high-water"
    );
    assert_eq!(a.stats.full_flushes, b.stats.full_flushes, "{tag}: full flushes");
    assert_eq!(a.stats.deadline_flushes, b.stats.deadline_flushes, "{tag}: deadline flushes");
    assert_eq!(a.stats.drain_flushes, b.stats.drain_flushes, "{tag}: drain flushes");
    assert_eq!(a.stats.family_flushes, b.stats.family_flushes, "{tag}: family flushes");
    assert_eq!(a.stats.deferred_offloads, b.stats.deferred_offloads, "{tag}: deferred");
    assert_eq!(a.stats.dropped_replies, b.stats.dropped_replies, "{tag}: dropped");
    assert_eq!(a.stats.degraded_requests, b.stats.degraded_requests, "{tag}: degraded");
    assert_eq!(a.stats.failover_redispatches, b.stats.failover_redispatches, "{tag}: failover");
    assert_eq!(a.stats.outage_rounds, b.stats.outage_rounds, "{tag}: outage rounds");
    assert_eq!(a.stats.scale_up_events, b.stats.scale_up_events, "{tag}: scale up");
    assert_eq!(a.stats.scale_down_events, b.stats.scale_down_events, "{tag}: scale down");
    assert_eq!(a.stats.shed_polls, b.stats.shed_polls, "{tag}: shed polls");
    assert_eq!(a.endpoint_dispatches, b.endpoint_dispatches, "{tag}: router spread");
    assert_eq!(a.mean_batch, b.mean_batch, "{tag}: mean batch");
    assert_eq!(a.cache.hits, b.cache.hits, "{tag}: cache hits");
    assert_eq!(a.cache.probes, b.cache.probes, "{tag}: cache probes");
    assert_eq!(a.sessions.len(), b.sessions.len(), "{tag}: session count");
    for (sa, sb) in a.sessions.iter().zip(b.sessions.iter()) {
        assert_eq!(sa.family, sb.family, "{tag}: family");
        assert_eq!(sa.class, sb.class, "{tag}: device class");
        assert_eq!(sa.arrival_round, sb.arrival_round, "{tag}: arrival round");
        assert_eq!(sa.departure_round, sb.departure_round, "{tag}: departure round");
        assert_eq!(sa.episodes.len(), sb.episodes.len(), "{tag}: episode count");
        for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
            assert_eq!(ma.latency_columns(), mb.latency_columns(), "{tag}: latency columns");
            assert_eq!(ma.cloud_events, mb.cloud_events, "{tag}: cloud events");
            assert_eq!(ma.edge_events, mb.edge_events, "{tag}: edge events");
            assert_eq!(ma.preemptions, mb.preemptions, "{tag}: preemptions");
            assert_eq!(ma.failovers, mb.failovers, "{tag}: failovers");
            assert_eq!(ma.cache_hits, mb.cache_hits, "{tag}: cache hits");
            assert_eq!(ma.deferred_offloads, mb.deferred_offloads, "{tag}: deferrals");
            assert_eq!(ma.rms_error, mb.rms_error, "{tag}: trajectory (rms)");
            assert_eq!(ma.success, mb.success, "{tag}: success");
        }
    }
}

/// `[devices]` left disabled (empty class list) while every knob that
/// *could* interact is hostile: draw-mode device mix, hostile (but
/// disabled) placement. Must perturb nothing.
fn hostile_disabled(sys: &SystemConfig) -> SystemConfig {
    let mut s = sys.clone();
    s.devices.classes = String::new();
    s.workload.device_mix = "draw".into();
    s.placement.enabled = false;
    s.placement.device_class = "lite".into();
    s.placement.max_edge_gb = 0.1;
    s.placement.prefix_ms_budget = 0.1;
    s.placement.queue_weight = 99.0;
    s.placement.gpu_capacity = 0.01;
    s
}

/// `classes = "cloudlet"` — the device zoo *armed* but populated only by
/// the no-op class. Every class-aware branch executes and must still be
/// bit-identical.
fn cloudlet_only(sys: &SystemConfig) -> SystemConfig {
    let mut s = sys.clone();
    s.devices.classes = "cloudlet".into();
    s
}

fn assert_all_completed(res: &FleetResult, tag: &str) {
    let expect = TaskKind::PickPlace.seq_len();
    for s in &res.sessions {
        for m in &s.episodes {
            assert_eq!(m.steps, expect, "{tag}: session {} wedged", s.session);
        }
    }
}

/// The serve-path matrix both bit-identity halves are checked over.
fn scenarios() -> Vec<(&'static str, SystemConfig, Vec<PolicyKind>)> {
    let mut plain = SystemConfig::default();
    plain.fleet.n_sessions = 4;
    let mut cache = SystemConfig::default();
    cache.fleet.n_sessions = 8;
    cache.cache.enabled = true;
    let mut chaos = SystemConfig::default();
    chaos.fleet.n_sessions = 6;
    chaos.fleet.endpoints = 3;
    chaos.faults = FaultsConfig::demo();
    let mut zoo = SystemConfig::default();
    zoo.fleet.n_sessions = 8;
    zoo.models.enabled = true;
    let mut pipeline = SystemConfig::default();
    pipeline.fleet.n_sessions = 6;
    pipeline.pipeline.enabled = true;
    pipeline.pipeline.overlap = true;
    pipeline.pipeline.speculate = true;
    let mut workload = SystemConfig::default();
    workload.fleet.n_sessions = 6;
    workload.workload.enabled = true;
    workload.workload.arrivals = "poisson".into();
    workload.workload.interarrival_rounds = 4.0;
    workload.workload.seed = 23;
    let mut autoscale = SystemConfig::default();
    autoscale.fleet.n_sessions = 8;
    autoscale.fleet.max_batch = 16;
    autoscale.fleet.max_inflight = 32;
    autoscale.fleet.batch_deadline_us = 50_000;
    autoscale.fleet.endpoints = 1;
    autoscale.autoscale.enabled = true;
    autoscale.autoscale.min_endpoints = 1;
    autoscale.autoscale.max_endpoints = 3;
    autoscale.autoscale.slo_queue = 2;
    autoscale.autoscale.sustain_rounds = 1;
    autoscale.autoscale.idle_rounds = 1;
    autoscale.autoscale.cooldown_rounds = 0;
    vec![
        ("plain", plain, vec![PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::VisionBased]),
        ("cache", cache, vec![PolicyKind::CloudOnly]),
        ("chaos", chaos, vec![PolicyKind::Rapid, PolicyKind::CloudOnly]),
        ("zoo", zoo, vec![PolicyKind::CloudOnly]),
        ("pipeline", pipeline, vec![PolicyKind::Rapid, PolicyKind::CloudOnly]),
        ("workload", workload, vec![PolicyKind::Rapid, PolicyKind::CloudOnly]),
        ("autoscale", autoscale, vec![PolicyKind::CloudOnly]),
    ]
}

#[test]
fn disabled_with_hostile_knobs_is_bit_identical_everywhere() {
    for (path, sys, kinds) in scenarios() {
        for kind in kinds {
            let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
            let run = Fleet::local(&hostile_disabled(&sys), TaskKind::PickPlace, kind).run();
            assert_bit_identical(&base, &run, &format!("disabled/{path}/{kind:?}"));
        }
    }
}

#[test]
fn cloudlet_only_enabled_is_bit_identical_everywhere() {
    // the armed-but-no-op half: every class branch (per-class plan table,
    // class-tagged signatures, scaled clocks, the snap funnel) actually
    // executes, with factors that reduce to exact identities
    for (path, sys, kinds) in scenarios() {
        for kind in kinds {
            let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
            let run = Fleet::local(&cloudlet_only(&sys), TaskKind::PickPlace, kind).run();
            assert_bit_identical(&base, &run, &format!("cloudlet/{path}/{kind:?}"));
        }
    }
}

#[test]
fn mixed_class_chaos_fleet_completes_replays_and_partitions_totals() {
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 9;
    sys.fleet.endpoints = 2;
    sys.models.enabled = true;
    sys.cache.enabled = true;
    sys.faults = FaultsConfig::demo();
    sys.devices.classes = "lite,nx,agx".into();
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    assert_all_completed(&res, "mixed chaos");
    // blocks assignment: 9 sessions over 3 classes = 3 each
    assert_eq!(res.classes.len(), 3, "{:?}", res.classes);
    for t in &res.classes {
        assert_eq!(t.sessions, 3, "{:?}", t.class);
        assert_ne!(t.class, DeviceClass::Cloudlet);
    }
    // per-class rollups exactly partition the fleet totals
    assert_eq!(res.classes.iter().map(|t| t.steps).sum::<u64>(), res.total_steps());
    assert_eq!(
        res.classes.iter().map(|t| t.cloud_events).sum::<u64>(),
        res.total_cloud_events()
    );
    let hits: u64 =
        res.sessions.iter().flat_map(|s| s.episodes.iter()).map(|m| m.cache_hits).sum();
    assert_eq!(res.classes.iter().map(|t| t.cache_hits).sum::<u64>(), hits);
    // exact seeded replay: classes change per-slot physics, never draws
    let again = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    assert_bit_identical(&res, &again, "mixed chaos replay");
}

#[test]
fn classes_plan_different_partition_points_on_the_live_fleet() {
    // one family (OpenVLA) across three classes: the 2 GB lite budget
    // hosts no split at all, so its sessions serve every step edge-only
    // even under Cloud-Only; nx and cloudlet keep offloading — the
    // fleet-level face of the planner's per-class argmin
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 9;
    sys.models.enabled = true;
    sys.models.families = "openvla".into();
    sys.devices.classes = "lite,nx,cloudlet".into();
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_all_completed(&res, "per-class plans");
    let by = |c: DeviceClass| res.classes.iter().find(|t| t.class == c).unwrap();
    assert_eq!(by(DeviceClass::Lite).cloud_events, 0, "lite must degrade to edge-only");
    assert!(by(DeviceClass::Nx).cloud_events > 0, "nx must keep offloading");
    assert!(by(DeviceClass::Cloudlet).cloud_events > 0, "cloudlet must keep offloading");
    for s in &res.sessions {
        assert_eq!(s.family, ModelFamily::OpenVlaAr);
    }
}

#[test]
fn cache_hits_never_cross_a_class_boundary() {
    // direct key check: same kinematic bin, different class, disjoint keys
    let cfg = rapid::config::CacheConfig::default();
    let frame = rapid::robot::SensorFrame {
        step: 0,
        q: rapid::robot::Jv::splat(0.3),
        dq: rapid::robot::Jv::splat(0.2),
        tau: rapid::robot::Jv::ZERO,
    };
    let base = rapid::cache::Signature::of(&cfg, 1, &frame, None, ModelFamily::Surrogate);
    for class in [DeviceClass::Agx, DeviceClass::Nx, DeviceClass::Lite] {
        let tagged = rapid::cache::Signature::of_class(
            &cfg,
            1,
            &frame,
            None,
            ModelFamily::Surrogate,
            class,
        );
        assert_ne!(base, tagged, "{class:?} must not share the cloudlet key");
    }
    // fleet-level face: the lockstep Cloud-Only fleet whose 8 identical
    // sessions cross-serve each other from the shared store stops doing
    // so across a class split — fewer hits, more wire inferences
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.cache.enabled = true;
    let uniform = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert!(uniform.cache.hits > 0, "uniform fleet must cross-serve: {:?}", uniform.cache);
    let mut split = sys.clone();
    // agx has no action grid and identical kinematics — only the key
    // differs, so any hit delta is pure class discrimination
    split.devices.classes = "cloudlet,agx".into();
    let mixed = Fleet::local(&split, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_all_completed(&mixed, "split cache");
    assert!(
        mixed.cache.hits < uniform.cache.hits,
        "class split must break cross-class reuse: {} vs {}",
        mixed.cache.hits,
        uniform.cache.hits
    );
    assert!(
        mixed.total_cloud_events() > uniform.total_cloud_events(),
        "broken reuse must show up as extra wire inferences"
    );
}

#[test]
fn blocks_device_mix_is_draw_free() {
    // enabling the device zoo in blocks mode must not consume a single
    // workload draw: arrivals, episode counts and families stay exactly
    // the classes-off plan
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.models.enabled = true;
    sys.workload.enabled = true;
    sys.workload.arrivals = "poisson".into();
    sys.workload.interarrival_rounds = 3.0;
    sys.workload.seed = 17;
    let base = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    let mut mixed_sys = sys.clone();
    mixed_sys.devices.classes = "lite,nx,agx".into();
    mixed_sys.workload.device_mix = "blocks".into();
    let mixed = Fleet::local(&mixed_sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    assert_eq!(base.sessions.len(), mixed.sessions.len());
    for (sa, sb) in base.sessions.iter().zip(mixed.sessions.iter()) {
        assert_eq!(sa.arrival_round, sb.arrival_round, "arrival schedule shifted");
        assert_eq!(sa.family, sb.family, "family assignment shifted");
        assert_eq!(sa.episodes.len(), sb.episodes.len(), "episode draws shifted");
        assert_eq!(sa.class, DeviceClass::Cloudlet);
        assert_ne!(sb.class, DeviceClass::Cloudlet, "mixed run must assign real classes");
    }
}

#[test]
fn unknown_class_names_and_bad_mix_are_load_errors() {
    // regression (the PR's headline bugfix): DeviceBudget::of used to
    // fall back to UNLIMITED for unrecognized strings — a typo silently
    // removed every budget. All three class-name surfaces now reject at
    // config load, naming the valid classes.
    let err = SystemConfig::from_toml("[devices]\nclasses = \"orin\"\n").unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("orin"), "error must name the bad class: {msg}");
    for known in ["cloudlet", "agx", "nx", "lite"] {
        assert!(msg.contains(known), "error must list valid classes: {msg}");
    }
    SystemConfig::from_toml("[placement]\ndevice_class = \"typo\"\n")
        .expect_err("placement typo must be a load error");
    SystemConfig::from_toml("[workload]\ndevice_mix = \"weird\"\n")
        .expect_err("bad device_mix must be a load error");
    // the happy paths still load
    let ok = SystemConfig::from_toml("[devices]\nclasses = \"lite, nx\"\n").unwrap();
    assert!(ok.devices.classes_enabled());
    assert_eq!(ok.devices.class_list(), vec![DeviceClass::Lite, DeviceClass::Nx]);
}

#[test]
fn shipped_configs_keep_the_device_zoo_disabled() {
    for name in ["configs/libero.toml", "configs/realworld.toml", "configs/stress_noise.toml",
        "configs/chaos.toml"]
    {
        let src = std::fs::read_to_string(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        let sys = SystemConfig::from_toml(&src).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!sys.devices.classes_enabled(), "{name} must ship [devices] disabled");
        assert_eq!(sys.workload.device_mix, "blocks", "{name}: device_mix default");
    }
}

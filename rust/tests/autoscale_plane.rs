//! Differential conformance suite for `[placement]` + `[autoscale]` —
//! multi-factor placement and the deterministic autoscaling control
//! plane.
//!
//! Three halves:
//!
//! * **Disabled ⇒ bit-identity.** `[placement]` and `[autoscale]`
//!   sections that are absent or disabled — whatever the other knobs
//!   say, however hostile — must leave the scheduler *exactly* the PR 8
//!   event loop: per-episode trajectories, flush causes, cache counters
//!   and fault-engine draws, across every serve path (plain fleets, the
//!   reuse cache, the chaos schedule, the model zoo, pipelined
//!   execution, dynamic arrivals).
//! * **Neutral-knobs placement is inert.** `[placement]` enabled with
//!   the unlimited device class, zero queue weight and nominal GPU
//!   capacity must reduce the multi-factor score to the single-factor
//!   cost bit-for-bit on the live zoo path.
//! * **Enabled holds the line.** The composed chaos + Poisson-workload +
//!   autoscale scenario completes with zero wedged sessions, both scale
//!   counters move, the shed gate bounds the backlog, placement budgets
//!   degrade over-budget families to edge-only serving, and every run
//!   replays bit-identically under the same seed.

use rapid::config::{FaultsConfig, PolicyKind, SystemConfig};
use rapid::robot::TaskKind;
use rapid::serve::{Fleet, FleetResult};
use rapid::vla::ModelFamily;

/// Full-strength bit-identity: scheduler counters, flush causes, router
/// spread, cache counters, control-plane counters, and exact per-episode
/// trajectory columns.
fn assert_bit_identical(a: &FleetResult, b: &FleetResult, tag: &str) {
    assert_eq!(a.stats.rounds, b.stats.rounds, "{tag}: rounds");
    assert_eq!(a.stats.batches, b.stats.batches, "{tag}: batches");
    assert_eq!(a.stats.batched_requests, b.stats.batched_requests, "{tag}: batched requests");
    assert_eq!(
        a.stats.multi_session_batches, b.stats.multi_session_batches,
        "{tag}: multi-session batches"
    );
    assert_eq!(a.stats.max_batch_observed, b.stats.max_batch_observed, "{tag}: batch high-water");
    assert_eq!(
        a.stats.max_inflight_observed, b.stats.max_inflight_observed,
        "{tag}: inflight high-water"
    );
    assert_eq!(a.stats.endpoint_errors, b.stats.endpoint_errors, "{tag}: endpoint errors");
    assert_eq!(a.stats.mixed_family_batches, b.stats.mixed_family_batches, "{tag}: mixed batches");
    assert_eq!(a.stats.spec_requests, b.stats.spec_requests, "{tag}: speculative requests");
    assert_eq!(a.stats.arrivals, b.stats.arrivals, "{tag}: arrivals");
    assert_eq!(
        a.stats.max_active_sessions, b.stats.max_active_sessions,
        "{tag}: active-session high-water"
    );
    assert_eq!(a.stats.full_flushes, b.stats.full_flushes, "{tag}: full flushes");
    assert_eq!(a.stats.deadline_flushes, b.stats.deadline_flushes, "{tag}: deadline flushes");
    assert_eq!(a.stats.drain_flushes, b.stats.drain_flushes, "{tag}: drain flushes");
    assert_eq!(a.stats.family_flushes, b.stats.family_flushes, "{tag}: family flushes");
    assert_eq!(a.stats.deferred_offloads, b.stats.deferred_offloads, "{tag}: deferred");
    assert_eq!(a.stats.dropped_replies, b.stats.dropped_replies, "{tag}: dropped");
    assert_eq!(a.stats.degraded_requests, b.stats.degraded_requests, "{tag}: degraded");
    assert_eq!(a.stats.failover_redispatches, b.stats.failover_redispatches, "{tag}: failover");
    assert_eq!(a.stats.outage_rounds, b.stats.outage_rounds, "{tag}: outage rounds");
    assert_eq!(a.stats.scale_up_events, b.stats.scale_up_events, "{tag}: scale up");
    assert_eq!(a.stats.scale_down_events, b.stats.scale_down_events, "{tag}: scale down");
    assert_eq!(a.stats.shed_polls, b.stats.shed_polls, "{tag}: shed polls");
    assert_eq!(
        a.stats.max_endpoints_observed, b.stats.max_endpoints_observed,
        "{tag}: endpoint high-water"
    );
    assert_eq!(a.endpoint_dispatches, b.endpoint_dispatches, "{tag}: router spread");
    assert_eq!(a.mean_batch, b.mean_batch, "{tag}: mean batch");
    assert_eq!(a.cache.hits, b.cache.hits, "{tag}: cache hits");
    assert_eq!(a.cache.probes, b.cache.probes, "{tag}: cache probes");
    assert_eq!(a.sessions.len(), b.sessions.len(), "{tag}: session count");
    for (sa, sb) in a.sessions.iter().zip(b.sessions.iter()) {
        assert_eq!(sa.family, sb.family, "{tag}: family");
        assert_eq!(sa.arrival_round, sb.arrival_round, "{tag}: arrival round");
        assert_eq!(sa.departure_round, sb.departure_round, "{tag}: departure round");
        assert_eq!(sa.episodes.len(), sb.episodes.len(), "{tag}: episode count");
        for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
            assert_eq!(ma.latency_columns(), mb.latency_columns(), "{tag}: latency columns");
            assert_eq!(ma.cloud_events, mb.cloud_events, "{tag}: cloud events");
            assert_eq!(ma.edge_events, mb.edge_events, "{tag}: edge events");
            assert_eq!(ma.preemptions, mb.preemptions, "{tag}: preemptions");
            assert_eq!(ma.failovers, mb.failovers, "{tag}: failovers");
            assert_eq!(ma.cache_hits, mb.cache_hits, "{tag}: cache hits");
            assert_eq!(ma.deferred_offloads, mb.deferred_offloads, "{tag}: deferrals");
            assert_eq!(ma.rms_error, mb.rms_error, "{tag}: trajectory (rms)");
            assert_eq!(ma.success, mb.success, "{tag}: success");
        }
    }
}

/// `[placement]` + `[autoscale]` sections that are present — with
/// hostile knobs — but disabled. Must perturb nothing.
fn hostile_disabled(sys: &SystemConfig) -> SystemConfig {
    let mut s = sys.clone();
    s.placement.enabled = false;
    s.placement.device_class = "lite".into();
    s.placement.max_edge_gb = 0.1;
    s.placement.prefix_ms_budget = 0.1;
    s.placement.queue_weight = 99.0;
    s.placement.gpu_capacity = 0.01;
    s.autoscale.enabled = false;
    s.autoscale.min_endpoints = 9;
    s.autoscale.max_endpoints = 1;
    s.autoscale.slo_queue = 0;
    s.autoscale.sustain_rounds = 0;
    s.autoscale.idle_rounds = 0;
    s.autoscale.cooldown_rounds = 0;
    s.autoscale.shed_queue = 1;
    s.autoscale.family_pools = true;
    s
}

/// The composed control-plane scenario: chaos fault schedule, Poisson
/// open-loop arrivals, deadline batching (a held partial batch is the
/// scaler's backlog signal), and the `[autoscale]` loop.
fn composed(shed_queue: usize) -> SystemConfig {
    let mut s = SystemConfig::default();
    s.fleet.n_sessions = 8;
    s.fleet.max_batch = 16;
    s.fleet.max_inflight = 32;
    s.fleet.batch_deadline_us = 50_000;
    s.fleet.endpoints = 1;
    s.faults = FaultsConfig::demo();
    s.workload.enabled = true;
    s.workload.arrivals = "poisson".into();
    s.workload.interarrival_rounds = 3.0;
    s.workload.seed = 17;
    s.autoscale.enabled = true;
    s.autoscale.min_endpoints = 1;
    s.autoscale.max_endpoints = 3;
    s.autoscale.slo_queue = 2;
    s.autoscale.sustain_rounds = 1;
    s.autoscale.idle_rounds = 1;
    s.autoscale.cooldown_rounds = 0;
    s.autoscale.shed_queue = shed_queue;
    s
}

fn assert_all_completed(res: &FleetResult, tag: &str) {
    let expect = TaskKind::PickPlace.seq_len();
    for s in &res.sessions {
        for m in &s.episodes {
            assert_eq!(m.steps, expect, "{tag}: session {} wedged", s.session);
        }
    }
}

#[test]
fn disabled_keeps_the_plain_fleet_bit_identical() {
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::VisionBased] {
        let mut sys = SystemConfig::default();
        sys.fleet.n_sessions = 4;
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let run = Fleet::local(&hostile_disabled(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &run, &format!("plain/{kind:?}"));
        assert_eq!(run.stats.scale_up_events, 0);
        assert_eq!(run.stats.shed_polls, 0);
    }
}

#[test]
fn disabled_keeps_the_reuse_cache_bit_identical() {
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.cache.enabled = true;
    let base = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert!(base.cache.hits > 0, "the cached fleet must actually hit");
    let run = Fleet::local(&hostile_disabled(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly)
        .run();
    assert_bit_identical(&base, &run, "cache");
}

#[test]
fn disabled_keeps_the_chaos_path_bit_identical() {
    // the fault engine's shared PRNG stream is the strictest differential:
    // one extra (or missing) draw anywhere — e.g. a control-plane branch
    // that consulted the engine — would shift every later drop decision
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.fleet.endpoints = 3;
    sys.faults = FaultsConfig::demo();
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let run = Fleet::local(&hostile_disabled(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &run, &format!("chaos/{kind:?}"));
    }
}

#[test]
fn disabled_keeps_the_zoo_path_bit_identical() {
    // the zoo replan path is where multi-factor placement plugs in: with
    // [placement] off the planner inputs must stay (family, link) only
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.models.enabled = true;
    let base = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert!(base.stats.family_flushes > 0, "the zoo fleet must exercise the family seal");
    let run = Fleet::local(&hostile_disabled(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly)
        .run();
    assert_bit_identical(&base, &run, "zoo");
}

#[test]
fn disabled_keeps_the_pipeline_path_bit_identical() {
    // stacked gates: [pipeline] fully on, [placement]/[autoscale] off —
    // speculative resubmission must not observe the control plane
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.pipeline.enabled = true;
    sys.pipeline.overlap = true;
    sys.pipeline.speculate = true;
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let run = Fleet::local(&hostile_disabled(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &run, &format!("pipeline/{kind:?}"));
    }
}

#[test]
fn disabled_keeps_dynamic_arrivals_bit_identical() {
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.workload.enabled = true;
    sys.workload.arrivals = "poisson".into();
    sys.workload.interarrival_rounds = 4.0;
    sys.workload.seed = 23;
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let run = Fleet::local(&hostile_disabled(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &run, &format!("workload/{kind:?}"));
    }
}

#[test]
fn neutral_placement_is_inert_on_the_live_zoo_path() {
    // [placement] enabled with the unlimited class, zero queue weight and
    // nominal capacity: the multi-factor score collapses to the
    // single-factor cost (x * 1.0 == x), so the live fleet must be
    // bit-identical to placement-off — the fleet-level face of the
    // planner-level reduction proptest
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.models.enabled = true;
    let base = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    let mut neutral = sys.clone();
    neutral.placement.enabled = true;
    neutral.placement.device_class = "cloudlet".into();
    neutral.placement.queue_weight = 0.0;
    neutral.placement.gpu_capacity = 1.0;
    let run = Fleet::local(&neutral, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_bit_identical(&base, &run, "neutral placement");
}

#[test]
fn composed_scenario_scales_completes_and_replays() {
    let sys = composed(0);
    for kind in [PolicyKind::CloudOnly, PolicyKind::Rapid] {
        let res = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        assert_all_completed(&res, &format!("composed/{kind:?}"));
        if kind == PolicyKind::CloudOnly {
            // the offload-everything policy generates sustained cloud
            // pressure: both sides of the control loop must move (Rapid's
            // chunked cadence makes its backlog shape workload-dependent,
            // so only completion + replay are pinned there)
            assert!(res.stats.scale_up_events > 0, "never scaled up: {:?}", res.stats);
            assert!(res.stats.scale_down_events > 0, "never drained: {:?}", res.stats);
            assert!(res.stats.max_endpoints_observed > 1, "high-water never moved");
        }
        assert!(res.stats.max_endpoints_observed <= 3, "{kind:?}: scaled past the ceiling");
        // exact seeded replay: the scaler reads only deterministic
        // counters — no clocks, no PRNG draws
        let again = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        assert_bit_identical(&res, &again, &format!("composed replay/{kind:?}"));
    }
}

#[test]
fn shed_gate_holds_the_backlog_and_nothing_wedges() {
    let sys = composed(4);
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_all_completed(&res, "shed");
    assert!(res.stats.shed_polls > 0, "the gate never engaged: {:?}", res.stats);
    assert!(res.stats.deferred_offloads > 0, "shed sessions must defer to the edge");
    // the batcher high-water mark respects the shed threshold
    assert!(
        res.stats.max_inflight_observed <= 4,
        "backlog exceeded shed_queue: {:?}",
        res.stats
    );
    let again = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_bit_identical(&res, &again, "shed replay");
}

#[test]
fn device_budget_degrades_over_budget_families_without_wedging() {
    // the `lite` class hosts no OpenVLA or Pi0 split: those zoo sessions
    // must serve every step edge-only (zero cloud events) and still
    // complete; the quantized family keeps offloading
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.models.enabled = true;
    sys.placement.enabled = true;
    sys.placement.device_class = "lite".into();
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_all_completed(&res, "budget");
    let mut saw_edge_only = false;
    let mut saw_offload = false;
    for t in &res.families {
        match t.family {
            ModelFamily::EdgeQuant => {
                assert!(t.cloud_events > 0, "in-budget family must offload: {t:?}");
                saw_offload = true;
            }
            ModelFamily::OpenVlaAr | ModelFamily::Pi0Diffusion => {
                assert_eq!(t.cloud_events, 0, "over-budget family offloaded: {t:?}");
                saw_edge_only = true;
            }
            ModelFamily::Surrogate => {}
        }
    }
    assert!(saw_edge_only && saw_offload, "zoo mix must cover both outcomes");
    let again = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_bit_identical(&res, &again, "budget replay");
}

#[test]
fn shipped_configs_keep_the_control_plane_disabled() {
    for name in ["configs/libero.toml", "configs/realworld.toml", "configs/stress_noise.toml",
        "configs/chaos.toml"]
    {
        let src = std::fs::read_to_string(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        let sys = SystemConfig::from_toml(&src).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!sys.placement.enabled, "{name} must ship [placement] disabled");
        assert!(!sys.autoscale.enabled, "{name} must ship [autoscale] disabled");
        assert!(sys.autoscale.min_endpoints >= 1, "{name}: drain floor below 1");
        assert!(
            sys.autoscale.max_endpoints >= sys.autoscale.min_endpoints,
            "{name}: scale ceiling below the floor"
        );
    }
}

#[test]
fn family_pools_restrict_spawned_endpoints_and_replay() {
    // zoo + family_pools, lockstep: block assignment puts the EdgeQuant
    // pair last in scheduler order, so round 0 ends holding a 2-request
    // EdgeQuant batch — with slo_queue 1 that backlog deterministically
    // spawns a pool endpoint advertising only the pressured family. The
    // two family-seal flushes of round 0 happen before any spawn, so
    // endpoint 0's dispatch row must cover at least two families.
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.fleet.max_batch = 16;
    sys.fleet.max_inflight = 32;
    sys.fleet.batch_deadline_us = 50_000;
    sys.models.enabled = true;
    sys.autoscale.enabled = true;
    sys.autoscale.min_endpoints = 1;
    sys.autoscale.max_endpoints = 3;
    sys.autoscale.slo_queue = 1;
    sys.autoscale.sustain_rounds = 1;
    sys.autoscale.idle_rounds = 1;
    sys.autoscale.cooldown_rounds = 0;
    sys.autoscale.family_pools = true;
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_all_completed(&res, "pools");
    assert!(res.stats.scale_up_events > 0, "pools scenario never scaled: {:?}", res.stats);
    let ep0_families =
        res.endpoint_family_dispatches[0].iter().filter(|&&d| d > 0).count();
    assert!(ep0_families >= 2, "endpoint 0 must serve the unpooled families: {ep0_families}");
    let again = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_bit_identical(&res, &again, "pools replay");
    assert_eq!(
        res.endpoint_family_dispatches, again.endpoint_family_dispatches,
        "pools: family spread must replay"
    );
}

//! Deterministic integration tests for the fleet scheduler: N concurrent
//! sessions over a shared, batched cloud path.
//!
//! The load-bearing properties:
//! * a seeded fleet run is exactly reproducible,
//! * cross-session batches never mix responses between sessions (proven
//!   by per-session equality with single-session runs of the same seed),
//! * backpressure caps in-flight cloud requests at the configured bound,
//! * coalescing emits genuinely multi-session wire batches.

use rapid::config::{PolicyKind, SystemConfig};
use rapid::metrics::EpisodeMetrics;
use rapid::net::{CloudClient, CloudServer};
use rapid::robot::TaskKind;
use rapid::serve::{fleet_seed, run_episode, Fleet};
use rapid::vla::AnalyticBackend;
use std::sync::atomic::Ordering;

fn fleet_sys(n: usize, max_batch: usize, max_inflight: usize, deadline_us: u64) -> SystemConfig {
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = n;
    sys.fleet.max_batch = max_batch;
    sys.fleet.max_inflight = max_inflight;
    sys.fleet.batch_deadline_us = deadline_us;
    sys
}

fn assert_metrics_eq(a: &EpisodeMetrics, b: &EpisodeMetrics, tag: &str) {
    assert_eq!(a.steps, b.steps, "{tag}: steps");
    assert_eq!(a.cloud_events, b.cloud_events, "{tag}: cloud_events");
    assert_eq!(a.edge_events, b.edge_events, "{tag}: edge_events");
    assert_eq!(a.preemptions, b.preemptions, "{tag}: preemptions");
    assert_eq!(a.retransmissions, b.retransmissions, "{tag}: retransmissions");
    assert_eq!(a.discarded_actions, b.discarded_actions, "{tag}: discarded_actions");
    assert_eq!(a.latency_columns(), b.latency_columns(), "{tag}: latency columns");
    assert_eq!(a.rms_error, b.rms_error, "{tag}: rms_error");
    assert_eq!(a.success, b.success, "{tag}: success");
    assert_eq!(a.edge_gb, b.edge_gb, "{tag}: edge_gb");
}

#[test]
fn fleet_of_8_completes_and_is_deterministic() {
    let sys = fleet_sys(8, 4, 16, 0);
    let a = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    let b = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();

    assert_eq!(a.sessions.len(), 8);
    for s in &a.sessions {
        assert_eq!(s.episodes.len(), 1, "session {}", s.session);
        assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len(), "session {}", s.session);
    }
    // exact replay: scheduler stats and every per-session metric
    assert_eq!(a.stats.rounds, b.stats.rounds);
    assert_eq!(a.stats.batches, b.stats.batches);
    assert_eq!(a.stats.batched_requests, b.stats.batched_requests);
    assert_eq!(a.stats.multi_session_batches, b.stats.multi_session_batches);
    assert_eq!(a.stats.max_inflight_observed, b.stats.max_inflight_observed);
    assert_eq!(a.endpoint_dispatches, b.endpoint_dispatches);
    for (sa, sb) in a.sessions.iter().zip(b.sessions.iter()) {
        for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
            assert_metrics_eq(ma, mb, &format!("replay session {}", sa.session));
        }
    }
}

#[test]
fn fleet_sessions_match_single_session_runs_exactly() {
    // Cross-session batches must never leak state between sessions: every
    // fleet session, batched or not, must equal the single-session run of
    // its seed operation for operation.
    let sys = fleet_sys(8, 4, 16, 0);
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    assert!(res.stats.batches > 0, "fleet never used the cloud path");

    for s in &res.sessions {
        let seed = fleet_seed(sys.episode.seed, s.session, 0);
        assert_eq!(seed, s.seed0);
        let strategy = rapid::policy::build(PolicyKind::Rapid, &sys);
        let mut edge = AnalyticBackend::edge(seed);
        let mut cloud = AnalyticBackend::cloud(seed);
        let solo =
            run_episode(&sys, TaskKind::PickPlace, strategy, &mut edge, &mut cloud, seed, false)
                .metrics;
        assert_metrics_eq(&s.episodes[0], &solo, &format!("session {}", s.session));
    }
}

#[test]
fn held_partial_batches_coalesce_across_sessions() {
    // With a long batch deadline, partial batches wait for company: RAPID
    // offloads from different sessions (different steps, even) land in one
    // wire batch — and holding a session suspended must not perturb its
    // virtual-time metrics.
    let sys = fleet_sys(8, 8, 16, 10_000_000);
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();

    let total_offloads: u64 = res.total_cloud_events();
    assert!(total_offloads >= 2, "too few offloads to coalesce: {total_offloads}");
    assert!(
        res.stats.multi_session_batches >= 1,
        "no multi-session batch despite held flushes: {:?}",
        res.stats
    );
    for s in &res.sessions {
        assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
        let seed = fleet_seed(sys.episode.seed, s.session, 0);
        let strategy = rapid::policy::build(PolicyKind::Rapid, &sys);
        let mut edge = AnalyticBackend::edge(seed);
        let mut cloud = AnalyticBackend::cloud(seed);
        let solo =
            run_episode(&sys, TaskKind::PickPlace, strategy, &mut edge, &mut cloud, seed, false)
                .metrics;
        assert_metrics_eq(&s.episodes[0], &solo, &format!("held session {}", s.session));
    }
}

#[test]
fn cloud_only_fleet_guarantees_multi_session_batches() {
    // CloudOnly sessions refill in lockstep (steps 0, 8, 16, ...), so the
    // scheduler structurally produces full cross-session batches: 8
    // requests per refill round, split into two batches of max_batch = 4.
    let sys = fleet_sys(8, 4, 16, 0);
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();

    let refill_rounds = (TaskKind::PickPlace.seq_len() + rapid::CHUNK - 1) / rapid::CHUNK; // 7
    let expect_batches = (refill_rounds * 2) as u64;
    assert_eq!(res.stats.batches, expect_batches);
    assert_eq!(res.stats.multi_session_batches, expect_batches);
    assert_eq!(res.stats.full_flushes, expect_batches);
    assert_eq!(res.stats.deadline_flushes, 0);
    assert_eq!(res.stats.drain_flushes, 0);
    assert_eq!(res.stats.max_batch_observed, 4);
    assert_eq!(res.stats.batched_requests, (8 * refill_rounds) as u64);
    assert_eq!(res.total_cloud_events(), (8 * refill_rounds) as u64);
    assert!((res.mean_batch - 4.0).abs() < 1e-12);
    for s in &res.sessions {
        assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
        assert_eq!(s.episodes[0].cloud_events, refill_rounds as u64);
    }
}

#[test]
fn backpressure_caps_inflight_at_bound() {
    // max_inflight = 2 over 8 simultaneous CloudOnly sessions: only the
    // first two offloads per refill round are admitted, the rest defer to
    // their (empty) edge slice — and the robot never starves.
    let sys = fleet_sys(8, 8, 2, 0);
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();

    let refill_rounds = (TaskKind::PickPlace.seq_len() + rapid::CHUNK - 1) / rapid::CHUNK; // 7
    assert!(res.stats.max_inflight_observed <= 2, "{:?}", res.stats);
    assert_eq!(res.total_cloud_events(), (2 * refill_rounds) as u64);
    assert_eq!(res.stats.deferred_offloads, (6 * refill_rounds) as u64);
    for s in &res.sessions {
        let m = &s.episodes[0];
        assert_eq!(m.steps, TaskKind::PickPlace.seq_len(), "session {}", s.session);
        // fixed poll order: sessions 0 and 1 always win admission
        if s.session < 2 {
            assert_eq!(m.cloud_events, refill_rounds as u64, "session {}", s.session);
            assert_eq!(m.deferred_offloads, 0, "session {}", s.session);
        } else {
            assert_eq!(m.cloud_events, 0, "session {}", s.session);
            assert_eq!(m.deferred_offloads, refill_rounds as u64, "session {}", s.session);
            assert_eq!(m.edge_events, refill_rounds as u64, "session {}", s.session);
        }
    }
}

#[test]
fn multi_episode_fleet_matches_solo_per_episode() {
    let mut sys = fleet_sys(3, 4, 16, 0);
    sys.fleet.episodes_per_session = 2;
    let res = Fleet::local(&sys, TaskKind::DrawerOpen, PolicyKind::Rapid).run();
    for s in &res.sessions {
        assert_eq!(s.episodes.len(), 2);
        for (ep, m) in s.episodes.iter().enumerate() {
            let seed = fleet_seed(sys.episode.seed, s.session, ep);
            let strategy = rapid::policy::build(PolicyKind::Rapid, &sys);
            let mut edge = AnalyticBackend::edge(seed);
            let mut cloud = AnalyticBackend::cloud(seed);
            let solo = run_episode(
                &sys,
                TaskKind::DrawerOpen,
                strategy,
                &mut edge,
                &mut cloud,
                seed,
                false,
            )
            .metrics;
            assert_metrics_eq(m, &solo, &format!("session {} episode {ep}", s.session));
        }
    }
}

#[test]
fn remote_fleet_batches_over_real_tcp() {
    // The same scheduler, transport swapped for real TCP: coalesced wire
    // frames hit two CloudServer endpoints; the router spreads batches.
    let servers: Vec<CloudServer> = (0..2)
        .map(|i| {
            CloudServer::start("127.0.0.1:0", 4, move || {
                Box::new(AnalyticBackend::cloud(100 + i as u64))
            })
            .unwrap()
        })
        .collect();
    let clients: Vec<CloudClient> =
        servers.iter().map(|s| CloudClient::connect(&s.addr.to_string()).unwrap()).collect();

    let sys = fleet_sys(4, 4, 16, 0);
    let res = Fleet::remote(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly, clients).run();

    let refill_rounds = (TaskKind::PickPlace.seq_len() + rapid::CHUNK - 1) / rapid::CHUNK; // 7
    for s in &res.sessions {
        assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
        assert_eq!(s.episodes[0].cloud_events, refill_rounds as u64);
    }
    assert_eq!(res.stats.batches, refill_rounds as u64);
    assert_eq!(res.stats.multi_session_batches, refill_rounds as u64);
    assert_eq!(res.stats.batched_requests, (4 * refill_rounds) as u64);

    // router spread: every batch went to exactly one endpoint, both used
    assert_eq!(res.endpoint_dispatches.iter().sum::<u64>(), refill_rounds as u64);
    assert!(res.endpoint_dispatches.iter().all(|&d| d > 0), "{:?}", res.endpoint_dispatches);

    let frames: u64 =
        servers.iter().map(|s| s.stats().batch_frames.load(Ordering::Relaxed)).sum();
    let requests: u64 = servers.iter().map(|s| s.stats().requests.load(Ordering::Relaxed)).sum();
    assert_eq!(frames, refill_rounds as u64);
    assert_eq!(requests, (4 * refill_rounds) as u64);

    for s in servers {
        s.shutdown();
    }
}

//! Differential conformance suite for the heterogeneous model zoo.
//!
//! Two halves:
//!
//! * **Disabled ⇒ bit-identity.** With `[models]` absent or
//!   `enabled = false` — whatever the other zoo knobs say — the fleet
//!   scheduler, the reuse cache and the chaos/failover paths replay the
//!   exact trajectories and metrics of the PR 3 scheduler (the same
//!   zero-perturbation contract `[faults]` and `[cache]` already honour).
//! * **Enabled ⇒ mixed fleets hold the line.** An 8-session mixed-family
//!   fleet completes under the chaos plan with no wedged session, no wire
//!   batch ever mixes model families, per-family counters exactly
//!   partition the fleet totals, family-tagged batches ride the real TCP
//!   path, and the compatibility-aware router respects endpoint
//!   advertisements.

use rapid::config::{FaultsConfig, PolicyKind, SystemConfig};
use rapid::net::{CloudClient, CloudServer};
use rapid::robot::TaskKind;
use rapid::serve::{Fleet, FleetResult};
use rapid::vla::{AnalyticBackend, ModelFamily};

fn assert_bit_identical(a: &FleetResult, b: &FleetResult, tag: &str) {
    assert_eq!(a.stats.rounds, b.stats.rounds, "{tag}: rounds");
    assert_eq!(a.stats.batches, b.stats.batches, "{tag}: batches");
    assert_eq!(a.stats.batched_requests, b.stats.batched_requests, "{tag}: batched requests");
    assert_eq!(a.stats.deferred_offloads, b.stats.deferred_offloads, "{tag}: deferred");
    assert_eq!(a.stats.dropped_replies, b.stats.dropped_replies, "{tag}: dropped");
    assert_eq!(a.stats.degraded_requests, b.stats.degraded_requests, "{tag}: degraded");
    assert_eq!(a.stats.outage_rounds, b.stats.outage_rounds, "{tag}: outage rounds");
    assert_eq!(a.cache.hits, b.cache.hits, "{tag}: cache hits");
    assert_eq!(a.cache.probes, b.cache.probes, "{tag}: cache probes");
    assert_eq!(a.cache.evictions, b.cache.evictions, "{tag}: cache evictions");
    assert_eq!(a.sessions.len(), b.sessions.len(), "{tag}: session count");
    for (sa, sb) in a.sessions.iter().zip(b.sessions.iter()) {
        assert_eq!(sa.episodes.len(), sb.episodes.len(), "{tag}: episode count");
        for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
            assert_eq!(ma.latency_columns(), mb.latency_columns(), "{tag}: latency columns");
            assert_eq!(ma.cloud_events, mb.cloud_events, "{tag}: cloud events");
            assert_eq!(ma.edge_events, mb.edge_events, "{tag}: edge events");
            assert_eq!(ma.preemptions, mb.preemptions, "{tag}: preemptions");
            assert_eq!(ma.failovers, mb.failovers, "{tag}: failovers");
            assert_eq!(ma.cache_hits, mb.cache_hits, "{tag}: cache hits");
            assert_eq!(ma.rms_error, mb.rms_error, "{tag}: trajectory (rms)");
            assert_eq!(ma.success, mb.success, "{tag}: success");
        }
    }
}

/// A `[models]` section that is present — with aggressive knobs — but
/// disabled. Must perturb nothing.
fn disabled_zoo(sys: &SystemConfig) -> SystemConfig {
    let mut s = sys.clone();
    s.models.enabled = false;
    s.models.families = "edgequant,pi0,openvla,surrogate".into();
    s
}

#[test]
fn disabled_models_keep_the_fleet_bit_identical() {
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::VisionBased] {
        let mut sys = SystemConfig::default();
        sys.fleet.n_sessions = 4;
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let run = Fleet::local(&disabled_zoo(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &run, &format!("{kind:?}"));
        assert_eq!(run.stats.family_flushes, 0);
        assert_eq!(run.stats.mixed_family_batches, 0);
    }
}

#[test]
fn disabled_models_keep_the_reuse_cache_bit_identical() {
    // the cache path exercises the family-discriminated signatures: with
    // the zoo off every signature carries the surrogate id, so hit
    // patterns must replay exactly
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.cache.enabled = true;
    let base = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert!(base.cache.hits > 0, "the cached fleet must actually hit");
    let run = Fleet::local(&disabled_zoo(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    assert_bit_identical(&base, &run, "cache");
}

#[test]
fn disabled_models_keep_the_chaos_path_bit_identical() {
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.fleet.endpoints = 3;
    sys.faults = FaultsConfig::demo();
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
        let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        let run = Fleet::local(&disabled_zoo(&sys), TaskKind::PickPlace, kind).run();
        assert_bit_identical(&base, &run, &format!("chaos/{kind:?}"));
    }
}

#[test]
fn enabled_surrogate_only_zoo_is_bit_identical_on_default_anchors() {
    // the surrogate family's catalog equals the default [devices]/[link]
    // anchors and its backends are the bare analytic pair, so a zoo that
    // serves *only* the surrogate replays the zoo-free fleet exactly —
    // the strongest form of the differential contract
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 4;
    let base = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::Rapid).run();
    let mut zoo = sys.clone();
    zoo.models.enabled = true;
    zoo.models.families = "surrogate".into();
    let run = Fleet::local(&zoo, TaskKind::PickPlace, PolicyKind::Rapid).run();
    assert_bit_identical(&base, &run, "surrogate-only zoo");
}

#[test]
fn mixed_fleet_completes_under_the_chaos_plan() {
    // the conformance suite's "enabled" half: 8 mixed-family sessions, 3
    // endpoints, the full demo fault schedule — crash, degrade, outage,
    // drops, delays — and nothing may wedge or mix
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.fleet.endpoints = 3;
    sys.faults = FaultsConfig::demo();
    sys.models.enabled = true;
    for kind in [PolicyKind::Rapid, PolicyKind::CloudOnly] {
        let res = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        assert_eq!(res.stats.mixed_family_batches, 0, "{kind:?} mixed a batch under chaos");
        for s in &res.sessions {
            for m in &s.episodes {
                assert_eq!(
                    m.steps,
                    TaskKind::PickPlace.seq_len(),
                    "{kind:?} session {} wedged under chaos",
                    s.session
                );
            }
        }
        // per-family counters exactly partition the fleet totals
        let steps: u64 = res.families.iter().map(|t| t.steps).sum();
        let cloud: u64 = res.families.iter().map(|t| t.cloud_events).sum();
        let batches: u64 = res.families.iter().map(|t| t.batches).sum();
        let reqs: u64 = res.families.iter().map(|t| t.batched_requests).sum();
        let sessions: usize = res.families.iter().map(|t| t.sessions).sum();
        assert_eq!(steps, res.total_steps(), "{kind:?}: family steps don't partition");
        assert_eq!(cloud, res.total_cloud_events(), "{kind:?}: family cloud events");
        assert_eq!(batches, res.stats.batches, "{kind:?}: family batches");
        assert_eq!(reqs, res.stats.batched_requests, "{kind:?}: family requests");
        assert_eq!(sessions, res.sessions.len(), "{kind:?}: family sessions");
        // chaos replays exactly
        let again = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
        assert_bit_identical(&res, &again, &format!("zoo-chaos replay {kind:?}"));
    }
}

#[test]
fn zoo_fleet_rides_family_tagged_frames_over_real_tcp() {
    // two real endpoints; the mixed fleet's batches go over the wire as
    // family-tagged zoo frames (+ plain frames for any surrogate batch)
    let s1 = CloudServer::start("127.0.0.1:0", 8, || Box::new(AnalyticBackend::cloud(1))).unwrap();
    let s2 = CloudServer::start("127.0.0.1:0", 8, || Box::new(AnalyticBackend::cloud(2))).unwrap();
    let c1 = CloudClient::connect(&s1.addr.to_string()).unwrap();
    let c2 = CloudClient::connect(&s2.addr.to_string()).unwrap();

    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.fleet.max_batch = 3;
    sys.models.enabled = true;
    let res = Fleet::remote(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly, vec![c1, c2]).run();
    assert_eq!(res.stats.mixed_family_batches, 0);
    assert!(res.total_cloud_events() > 0, "the wire must actually serve");
    for s in &res.sessions {
        assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
    }
    let zoo_frames = s1.stats().zoo_frames.load(std::sync::atomic::Ordering::Relaxed)
        + s2.stats().zoo_frames.load(std::sync::atomic::Ordering::Relaxed);
    assert!(zoo_frames > 0, "no family-tagged frame ever crossed the wire");
    s1.shutdown();
    s2.shutdown();
}

#[test]
fn compatibility_router_respects_endpoint_advertisements() {
    // endpoint 0 serves only the AR family; endpoint 1 everything. Every
    // non-AR dispatch must avoid endpoint 0, and the fleet still
    // completes with zero degradation (endpoint 1 covers the rest).
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.fleet.endpoints = 2;
    sys.models.enabled = true;
    let mut fleet = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly);
    fleet.restrict_endpoint(0, &[ModelFamily::OpenVlaAr]);
    let res = fleet.run();
    assert_eq!(res.stats.degraded_requests, 0, "endpoint 1 must cover every family");
    for fam in [ModelFamily::Surrogate, ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant] {
        assert_eq!(
            res.endpoint_family_dispatches[0][fam.id() as usize],
            0,
            "{fam:?} dispatched to a non-advertiser"
        );
    }
    // AR batches exist and someone served them
    let ar: u64 = res
        .endpoint_family_dispatches
        .iter()
        .map(|e| e[ModelFamily::OpenVlaAr.id() as usize])
        .sum();
    assert!(ar > 0, "the AR family never dispatched");
    for s in &res.sessions {
        assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
    }
}

#[test]
fn shared_store_never_cross_serves_families_in_a_live_fleet() {
    // zoo + shared cache: 8 lockstep CloudOnly sessions all start in the
    // same kinematic state, so without the family discriminant the first
    // family's round-0 admission would cross-serve every other family's
    // round-0 probe. max_batch 2 makes each family block flush mid-round:
    // its third session (where one exists) hits its *own* family's
    // answer, while the next family's probes — same joint state — miss.
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 8;
    sys.fleet.max_batch = 2;
    sys.cache.enabled = true;
    sys.models.enabled = true;
    let res = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    // per-family hits live inside the family rollup; totals must agree
    let hits: u64 = res.families.iter().map(|t| t.cache_hits).sum();
    let per_episode: u64 =
        res.sessions.iter().flat_map(|s| s.episodes.iter()).map(|m| m.cache_hits).sum();
    assert_eq!(hits, per_episode);
    assert_eq!(hits, res.cache.hits);
    // same-family sessions still share answers (the cache is not dead)...
    assert!(res.cache.hits > 0, "same-family sessions must still share: {:?}", res.cache);
    // ...and wire + cache exactly partition each family's own offload
    // schedule (sessions × ceil(steps / family chunk)): a single
    // cross-family hit would shift a family's wire count below its line
    let seq = TaskKind::PickPlace.seq_len() as u64;
    for t in &res.families {
        let chunk = rapid::vla::FamilyProfile::of(t.family).chunk_len as u64;
        let dispatches = t.sessions as u64 * seq.div_ceil(chunk);
        assert_eq!(
            t.cloud_events + t.cache_hits,
            dispatches,
            "{:?}: wire + cache must partition the family's schedule",
            t.family
        );
        assert!(t.cloud_events > 0, "{:?} never paid the wire — cross-served?", t.family);
    }
    for s in &res.sessions {
        assert_eq!(s.episodes[0].steps, TaskKind::PickPlace.seq_len());
    }
}

#[test]
fn zoo_acceptance_on_the_shipped_config() {
    // configs/libero.toml with [models] flipped on: the full acceptance
    // path end to end — mixed fleet, no mixing, RAPID beats Cloud-Only
    // mean latency at equal success for every family
    let src = std::fs::read_to_string("configs/libero.toml").expect("configs/libero.toml");
    let mut sys = SystemConfig::from_toml(&src).expect("parse libero.toml");
    sys.fleet.n_sessions = 8;
    let (_, rows, arms) = rapid::experiments::hetero::run(&sys, TaskKind::PickPlace);
    for a in &arms {
        assert_eq!(a.mixed_family_batches, 0, "{:?}", a.policy);
    }
    for fam in [ModelFamily::OpenVlaAr, ModelFamily::Pi0Diffusion, ModelFamily::EdgeQuant] {
        let find = |k: PolicyKind| rows.iter().find(|r| r.policy == k && r.family == fam).unwrap();
        let rapid = find(PolicyKind::Rapid);
        let cloud = find(PolicyKind::CloudOnly);
        assert!(rapid.completed && cloud.completed, "{fam:?} wedged");
        assert!(
            rapid.mean_lat < cloud.mean_lat,
            "{fam:?}: RAPID {} !< Cloud-Only {}",
            rapid.mean_lat,
            cloud.mean_lat
        );
        assert_eq!(rapid.success, cloud.success, "{fam:?}: unequal success");
    }
}

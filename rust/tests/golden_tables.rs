//! Golden-output regression tests for the paper-table generators: a fixed
//! seed must render the exact same table cells forever.
//!
//! Snapshots live in `rust/tests/golden/`. On first run (or with
//! `GOLDEN_UPDATE=1`) a test writes its snapshot and passes with a notice
//! — commit the generated files. Afterwards any drift in the rendered
//! cells fails the test, so the generators behind the paper's Tables I/II
//! cannot silently change.

use rapid::config::SystemConfig;
use rapid::experiments::{tab1, tab2, Backends};
use std::fs;
use std::path::Path;

const GOLDEN_DIR: &str = "rust/tests/golden";
const GOLDEN_SEED: u64 = 1234;

fn check_golden(name: &str, rendered: &str) {
    let path = format!("{GOLDEN_DIR}/{name}.txt");
    let update = std::env::var_os("GOLDEN_UPDATE").is_some();
    if update || !Path::new(&path).exists() {
        fs::create_dir_all(GOLDEN_DIR).unwrap_or_else(|e| panic!("mkdir {GOLDEN_DIR}: {e}"));
        fs::write(&path, rendered).unwrap_or_else(|e| panic!("write {path}: {e}"));
        eprintln!("golden: wrote snapshot {path} — commit this file");
        return;
    }
    let want = fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"));
    assert_eq!(
        rendered, want,
        "{name}: rendered table drifted from the golden snapshot; \
         rerun with GOLDEN_UPDATE=1 only if the change is intentional"
    );
}

fn render_tab1() -> String {
    let sys = SystemConfig::default();
    let mut b = Backends::analytic(GOLDEN_SEED);
    tab1::run(&sys, &mut b, 2).0.render()
}

fn render_tab2() -> String {
    let sys = SystemConfig::default();
    let mut b = Backends::analytic(GOLDEN_SEED);
    tab2::run(&sys, &mut b, 2).0.render()
}

#[test]
fn tab1_fixed_seed_renders_exact_cells() {
    let first = render_tab1();
    let second = render_tab1();
    assert_eq!(first, second, "tab1 generator is nondeterministic under a fixed seed");
    assert!(first.contains("TABLE I"), "unexpected header:\n{first}");
    check_golden("tab1", &first);
}

#[test]
fn tab2_fixed_seed_renders_exact_cells() {
    let first = render_tab2();
    let second = render_tab2();
    assert_eq!(first, second, "tab2 generator is nondeterministic under a fixed seed");
    assert!(first.contains("TABLE II"), "unexpected header:\n{first}");
    check_golden("tab2", &first);
}

//! Differential conformance suite for `[trace]` — the deterministic
//! span tracer, metrics registry, and wedge flight recorder.
//!
//! Three halves:
//!
//! * **Disabled ⇒ bit-identity.** A `[trace]` section that is absent or
//!   disabled (whatever the other knobs say) must leave the scheduler
//!   *exactly* the PR 7 event loop — not just totals, but per-episode
//!   trajectories, flush causes, cache counters and fault-engine draws —
//!   across every serve path: plain fleets, the reuse cache, the
//!   chaos/failover schedule, the model zoo, the pipeline, and dynamic
//!   arrivals.
//! * **Enabled ⇒ still bit-identity, plus artifacts.** Tracing records
//!   spans but draws nothing and never advances the clock, so a traced
//!   fleet is bit-identical to the untraced one, and two same-seed
//!   traced runs emit byte-identical Chrome JSON / JSONL / registry
//!   dumps.
//! * **The wedge postmortem.** A fault schedule that kills every
//!   endpoint mid-dispatch with retries exhausted must leave a flight
//!   recorder that names the stuck session, its recent events, and the
//!   pending batch's flush cause.

use rapid::config::{FaultsConfig, PolicyKind, SystemConfig};
use rapid::faults::{FaultEngine, FaultPlan};
use rapid::obs::{demo, FlightKind, Stage};
use rapid::robot::TaskKind;
use rapid::serve::{Fleet, FleetResult};

/// Full-strength bit-identity: scheduler counters, flush causes, router
/// spread, cache counters, speculation counters, and exact per-episode
/// trajectory columns.
fn assert_bit_identical(a: &FleetResult, b: &FleetResult, tag: &str) {
    assert_eq!(a.stats.rounds, b.stats.rounds, "{tag}: rounds");
    assert_eq!(a.stats.batches, b.stats.batches, "{tag}: batches");
    assert_eq!(a.stats.batched_requests, b.stats.batched_requests, "{tag}: batched requests");
    assert_eq!(a.stats.multi_session_batches, b.stats.multi_session_batches, "{tag}: multi");
    assert_eq!(a.stats.full_flushes, b.stats.full_flushes, "{tag}: full flushes");
    assert_eq!(a.stats.deadline_flushes, b.stats.deadline_flushes, "{tag}: deadline flushes");
    assert_eq!(a.stats.drain_flushes, b.stats.drain_flushes, "{tag}: drain flushes");
    assert_eq!(a.stats.family_flushes, b.stats.family_flushes, "{tag}: family flushes");
    assert_eq!(a.stats.deferred_offloads, b.stats.deferred_offloads, "{tag}: deferred");
    assert_eq!(a.stats.dropped_replies, b.stats.dropped_replies, "{tag}: dropped");
    assert_eq!(a.stats.degraded_requests, b.stats.degraded_requests, "{tag}: degraded");
    assert_eq!(a.stats.failover_redispatches, b.stats.failover_redispatches, "{tag}: failover");
    assert_eq!(a.stats.outage_rounds, b.stats.outage_rounds, "{tag}: outage rounds");
    assert_eq!(a.stats.spec_requests, b.stats.spec_requests, "{tag}: spec requests");
    assert_eq!(a.endpoint_dispatches, b.endpoint_dispatches, "{tag}: router spread");
    assert_eq!(a.mean_batch, b.mean_batch, "{tag}: mean batch");
    assert_eq!(a.cache.hits, b.cache.hits, "{tag}: cache hits");
    assert_eq!(a.cache.probes, b.cache.probes, "{tag}: cache probes");
    assert_eq!(a.cache.evictions, b.cache.evictions, "{tag}: cache evictions");
    assert_eq!(a.sessions.len(), b.sessions.len(), "{tag}: session count");
    for (sa, sb) in a.sessions.iter().zip(b.sessions.iter()) {
        assert_eq!(sa.family, sb.family, "{tag}: family");
        assert_eq!(sa.arrival_round, sb.arrival_round, "{tag}: arrival round");
        assert_eq!(sa.departure_round, sb.departure_round, "{tag}: departure round");
        assert_eq!(sa.episodes.len(), sb.episodes.len(), "{tag}: episode count");
        for (ma, mb) in sa.episodes.iter().zip(sb.episodes.iter()) {
            assert_eq!(ma.latency_columns(), mb.latency_columns(), "{tag}: latency columns");
            assert_eq!(ma.cloud_events, mb.cloud_events, "{tag}: cloud events");
            assert_eq!(ma.edge_events, mb.edge_events, "{tag}: edge events");
            assert_eq!(ma.preemptions, mb.preemptions, "{tag}: preemptions");
            assert_eq!(ma.failovers, mb.failovers, "{tag}: failovers");
            assert_eq!(ma.cache_hits, mb.cache_hits, "{tag}: cache hits");
            assert_eq!(ma.overhead_ms, mb.overhead_ms, "{tag}: overhead");
            assert_eq!(ma.spec_dispatches, mb.spec_dispatches, "{tag}: spec dispatches");
            assert_eq!(ma.spec_confirms, mb.spec_confirms, "{tag}: spec confirms");
            assert_eq!(ma.spec_rollbacks, mb.spec_rollbacks, "{tag}: spec rollbacks");
            assert_eq!(ma.overlap_hidden_ms, mb.overlap_hidden_ms, "{tag}: hidden ms");
            assert_eq!(ma.rms_error, mb.rms_error, "{tag}: trajectory (rms)");
            assert_eq!(ma.success, mb.success, "{tag}: success");
        }
    }
}

/// A `[trace]` section that is present — with hostile knobs — but
/// disabled. Must perturb nothing.
fn disabled_trace(sys: &SystemConfig) -> SystemConfig {
    let mut s = sys.clone();
    s.trace.enabled = false;
    s.trace.max_spans = 0;
    s.trace.flight_events = 0;
    s
}

/// `[trace]` armed with the shipped default knobs.
fn enabled_trace(sys: &SystemConfig) -> SystemConfig {
    let mut s = sys.clone();
    s.trace.enabled = true;
    s
}

/// The serve paths the differential sweep covers, as (tag, config,
/// policies) tuples built fresh per call.
fn paths() -> Vec<(&'static str, SystemConfig, Vec<PolicyKind>)> {
    let mut plain = SystemConfig::default();
    plain.fleet.n_sessions = 4;

    let mut cache = SystemConfig::default();
    cache.fleet.n_sessions = 8;
    cache.cache.enabled = true;

    let mut chaos = SystemConfig::default();
    chaos.fleet.n_sessions = 6;
    chaos.fleet.endpoints = 3;
    chaos.faults = FaultsConfig::demo();

    let mut zoo = SystemConfig::default();
    zoo.fleet.n_sessions = 8;
    zoo.models.enabled = true;

    let mut pipe = SystemConfig::default();
    pipe.fleet.n_sessions = 6;
    pipe.pipeline.enabled = true;
    pipe.pipeline.overlap = true;
    pipe.pipeline.speculate = true;

    let mut poisson = SystemConfig::default();
    poisson.fleet.n_sessions = 6;
    poisson.workload.enabled = true;
    poisson.workload.arrivals = "poisson".into();
    poisson.workload.interarrival_rounds = 4.0;
    poisson.workload.seed = 23;

    vec![
        ("plain", plain, vec![PolicyKind::Rapid, PolicyKind::CloudOnly, PolicyKind::VisionBased]),
        ("cache", cache, vec![PolicyKind::CloudOnly]),
        ("chaos", chaos, vec![PolicyKind::Rapid, PolicyKind::CloudOnly]),
        ("zoo", zoo, vec![PolicyKind::CloudOnly]),
        ("pipeline", pipe, vec![PolicyKind::Rapid, PolicyKind::CloudOnly]),
        ("poisson", poisson, vec![PolicyKind::Rapid, PolicyKind::CloudOnly]),
    ]
}

#[test]
fn disabled_trace_keeps_every_serve_path_bit_identical() {
    for (tag, sys, kinds) in paths() {
        for kind in kinds {
            let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
            let run = Fleet::local(&disabled_trace(&sys), TaskKind::PickPlace, kind).run();
            assert_bit_identical(&base, &run, &format!("{tag}/disabled/{kind:?}"));
            assert!(run.trace.is_none(), "{tag}: disabled trace must record nothing");
            assert!(run.flight.is_none(), "{tag}: disabled trace must not arm the recorder");
        }
    }
}

#[test]
fn enabled_trace_is_bit_identical_and_records_spans() {
    // the zero-draw / zero-clock contract: arming [trace] changes not a
    // single scheduler decision on any serve path
    for (tag, sys, kinds) in paths() {
        for kind in kinds {
            let base = Fleet::local(&sys, TaskKind::PickPlace, kind).run();
            let run = Fleet::local(&enabled_trace(&sys), TaskKind::PickPlace, kind).run();
            assert_bit_identical(&base, &run, &format!("{tag}/enabled/{kind:?}"));
            let tr = run.trace.as_ref().expect("enabled trace must be harvested");
            if base.stats.batches > 0 {
                assert!(!tr.is_empty(), "{tag}/{kind:?}: a batching fleet must record spans");
                assert!(
                    tr.count_stage(Stage::CloudQueue) > 0,
                    "{tag}/{kind:?}: every flushed request owes a CloudQueue span"
                );
            }
            assert!(run.flight.is_some(), "{tag}: enabled trace arms the recorder");
        }
    }
}

#[test]
fn traced_chaos_run_replays_byte_identical_artifacts() {
    // the trace is itself a deterministic artifact: two same-seed runs
    // under the demo fault schedule emit identical bytes for all three
    // export formats
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.fleet.endpoints = 3;
    sys.faults = FaultsConfig::demo();
    let sys = enabled_trace(&sys);
    let a = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    let b = Fleet::local(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    let (ta, tb) = (a.trace.as_ref().unwrap(), b.trace.as_ref().unwrap());
    assert!(ta.len() > 0, "the chaos fleet must record spans");
    assert_eq!(ta.to_chrome_json(), tb.to_chrome_json(), "chrome JSON must replay exactly");
    assert_eq!(ta.to_jsonl(), tb.to_jsonl(), "JSONL must replay exactly");
    assert_eq!(a.registry().to_json(), b.registry().to_json(), "registry must replay exactly");
    // chaos exercises the fault stages, not just the happy path
    assert!(ta.count_stage(Stage::Failover) > 0, "demo schedule must record failovers");
    assert!(ta.count_stage(Stage::Outage) > 0, "demo schedule must record outage rounds");
}

#[test]
fn trace_artifacts_parse_and_hide_the_endpoint_sentinel() {
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.cache.enabled = true;
    let res = Fleet::local(&enabled_trace(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    let tr = res.trace.as_ref().unwrap();
    let doc = tr.to_chrome_json();
    let v = rapid::config::json::parse_json(&doc).expect("chrome trace JSON must parse");
    let events = v.get("traceEvents").and_then(|e| e.as_list()).expect("traceEvents array");
    assert_eq!(events.len(), tr.len(), "one event per span");
    for line in tr.to_jsonl().lines() {
        rapid::config::json::parse_json(line).expect("every JSONL line parses");
    }
    assert!(!doc.contains("4294967295"), "NO_ENDPOINT must serialize as -1");
}

#[test]
fn forced_wedge_dumps_a_usable_flight_postmortem() {
    // the satellite pin: kill every endpoint mid-dispatch (one crashed
    // for good, the survivor dropping every reply) with retries
    // exhausted — the fleet degrades instead of wedging, and the flight
    // recorder must name the stuck session, its event tail, and the
    // pending batch's flush cause
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 4;
    sys.fleet.endpoints = 2;
    sys.trace.enabled = true;
    let plan = FaultPlan::none().crash(1, 0, u64::MAX).drop_replies(0, u64::MAX, 1.0);
    let engine = FaultEngine::new(plan, sys.episode.seed, 250.0, 0);
    let res =
        Fleet::local_with_faults(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly, engine).run();
    assert!(res.stats.degraded_requests > 0, "the schedule must force degraded dispatches");

    let fl = res.flight.as_ref().expect("enabled trace arms the recorder");
    let suspect = fl.suspect().expect("a degraded fleet names a suspect");
    let tail = fl.tail(suspect);
    assert!(!tail.is_empty(), "the suspect session has recorded events");
    assert!(
        tail.iter().any(|e| e.kind == FlightKind::Degraded),
        "the suspect's tail shows the degraded dispatch"
    );
    let report = fl.report();
    assert!(report.contains(&format!("session {suspect} stuck")), "{report}");
    assert!(report.contains("cause"), "report names the pending batch's flush cause:\n{report}");
    assert!(report.contains("request(s)"), "report names the pending batch size:\n{report}");
    assert!(report.contains("all endpoints exhausted"), "{report}");

    // the postmortem is still a deterministic artifact
    let engine2 = FaultPlan::none().crash(1, 0, u64::MAX).drop_replies(0, u64::MAX, 1.0);
    let engine2 = FaultEngine::new(engine2, sys.episode.seed, 250.0, 0);
    let res2 =
        Fleet::local_with_faults(&sys, TaskKind::PickPlace, PolicyKind::CloudOnly, engine2).run();
    assert_eq!(res2.flight.as_ref().unwrap().report(), report, "postmortem replays exactly");
}

#[test]
fn trace_demo_covers_every_stage_kind_with_byte_identical_artifacts() {
    // what the trace-smoke CI step pins, exercised hermetically: the
    // two-fleet demo produces at least one span of every stage kind and
    // replays byte-identically
    let sys = SystemConfig::default();
    let a = demo::run_trace_demo(&sys, 6);
    let missing = demo::missing_stages(&a.stage_counts);
    assert!(missing.is_empty(), "demo missed stage kinds: {missing:?}");
    let v = rapid::config::json::parse_json(&a.chrome_json).expect("demo chrome JSON parses");
    assert!(
        !v.get("traceEvents").and_then(|e| e.as_list()).expect("traceEvents").is_empty(),
        "demo trace is non-empty"
    );
    let b = demo::run_trace_demo(&sys, 6);
    assert_eq!(a.chrome_json, b.chrome_json, "demo chrome JSON replays exactly");
    assert_eq!(a.jsonl, b.jsonl, "demo JSONL replays exactly");
    assert_eq!(a.registry.to_json(), b.registry.to_json(), "demo registry replays exactly");
}

#[test]
fn registry_carries_per_stage_histograms_and_fleet_counters() {
    let mut sys = SystemConfig::default();
    sys.fleet.n_sessions = 6;
    sys.cache.enabled = true;
    let res = Fleet::local(&enabled_trace(&sys), TaskKind::PickPlace, PolicyKind::CloudOnly).run();
    let reg = res.registry();
    // counters mirror FleetStats exactly
    assert_eq!(reg.counter("rounds"), Some(res.stats.rounds));
    assert_eq!(reg.counter("batches"), Some(res.stats.batches));
    assert_eq!(reg.counter("cache/probes"), Some(res.cache.probes));
    assert_eq!(reg.counter("cache/hits"), Some(res.cache.hits));
    let tr = res.trace.as_ref().unwrap();
    assert_eq!(reg.counter("trace/spans"), Some(tr.len() as u64));
    // every recorded stage owns a histogram with the matching count
    for stage in Stage::ALL {
        let n = tr.count_stage(stage);
        match reg.histogram(stage.name()) {
            Some(h) => assert_eq!(h.count(), n, "{}: histogram count", stage.name()),
            None => assert_eq!(n, 0, "{}: recorded spans need a histogram", stage.name()),
        }
    }
    // the render includes the histogram table; the JSON parses
    let rendered = reg.render("fleet counters");
    assert!(rendered.contains("latency histograms"), "{rendered}");
    assert!(rendered.contains("cloud_queue"), "{rendered}");
    rapid::config::json::parse_json(&reg.to_json()).expect("metrics JSON parses");
}

//! Regenerates paper Table IV: the real-world deployment preset
//! (Edge-Only / Cloud-Only / ISAR / RAPID).
//!
//! Expected shape: same ordering as Table III with higher absolute
//! latencies (slower edge SoC, lossier wireless link); RAPID ≈ 1.73x
//! faster than the vision baseline.

use rapid::config::presets::realworld_preset;
use rapid::experiments::{tab345, Backends};

fn main() {
    let sys = realworld_preset();
    let mut backends = Backends::pjrt_or_analytic(sys.episode.seed);
    let t0 = std::time::Instant::now();
    let (table, rows) = tab345::tab4(&sys, &mut backends, 4);
    print!("{}", table.render());
    println!("RAPID speedup vs vision baseline: {:.2}x (paper: 1.73x)", rows.speedup_vs_vision());
    println!("[bench wall-clock {:.1}s]", t0.elapsed().as_secs_f64());
}

//! Runtime hot-path benchmarks (EXPERIMENTS.md §Perf): PJRT inference
//! latency for both variants with device-resident weights, plus the
//! end-to-end episode driver throughput on both backend kinds.

use rapid::benchkit::{header, Bench};
use rapid::config::{PolicyKind, SystemConfig};
use rapid::experiments::Backends;
use rapid::robot::TaskKind;
use rapid::serve::run_episode;
use rapid::{D_PROP, D_VIS};

fn main() {
    let sys = SystemConfig::default();
    let mut bench = Bench::new().with_budget_ms(2000.0);

    let obs = {
        let mut o = [0f32; D_VIS];
        o[0] = 0.3;
        o[7] = 0.5;
        o[15] = 0.5;
        o
    };
    let proprio = [0f32; D_PROP];

    // §Perf before/after: the naive path re-uploads the weight blob on
    // every call; the shipped runtime keeps weights device-resident.
    #[cfg(feature = "pjrt")]
    if let Ok(meta) =
        rapid::runtime::ArtifactMeta::load(rapid::runtime::ArtifactMeta::default_dir())
    {
        if let Ok(client) = rapid::runtime::RuntimeClient::cpu() {
            header("weights upload cost (naive per-call path, avoided)");
            let cloud = meta.variant("cloud").unwrap();
            let host = rapid::runtime::artifact::read_weights(&cloud.weights_path).unwrap();
            bench.run("naive.cloud.weights_upload", || {
                std::hint::black_box(
                    client
                        .raw()
                        .buffer_from_host_buffer::<f32>(&host, &[host.len()], None)
                        .unwrap(),
                );
            });
        }
    }

    match Backends::try_pjrt() {
        Ok(mut b) => {
            header("PJRT inference (device-resident weights)");
            bench.run("pjrt.edge.infer", || {
                std::hint::black_box(b.edge.infer(&obs, &proprio, 1));
            });
            bench.run("pjrt.cloud.infer", || {
                std::hint::black_box(b.cloud.infer(&obs, &proprio, 1));
            });
            println!(
                "measured means: edge {:.0}µs cloud {:.0}µs",
                b.edge.mean_us(),
                b.cloud.mean_us()
            );

            header("end-to-end episode (PJRT models, RAPID policy)");
            let mut seed = 0u64;
            bench.run("episode.pickplace.rapid.pjrt", || {
                seed += 1;
                let strategy = rapid::policy::build(PolicyKind::Rapid, &sys);
                std::hint::black_box(run_episode(
                    &sys,
                    TaskKind::PickPlace,
                    strategy,
                    b.edge.as_mut(),
                    b.cloud.as_mut(),
                    seed,
                    false,
                ));
            });
        }
        Err(e) => println!("[perf_runtime] PJRT unavailable ({e}); skipping PJRT section"),
    }

    header("end-to-end episode (analytic models, RAPID policy)");
    let mut b = Backends::analytic(1);
    let mut seed = 0u64;
    bench.run("episode.pickplace.rapid.analytic", || {
        seed += 1;
        let strategy = rapid::policy::build(PolicyKind::Rapid, &sys);
        std::hint::black_box(run_episode(
            &sys,
            TaskKind::PickPlace,
            strategy,
            b.edge.as_mut(),
            b.cloud.as_mut(),
            seed,
            false,
        ));
    });
}

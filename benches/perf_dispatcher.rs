//! L3 hot-path microbenchmarks (§VI-D.2 overhead + EXPERIMENTS.md §Perf):
//! dispatcher tick cost, decision cost, rolling-stat update, and the
//! fraction of the 500 Hz sensor budget consumed.

use rapid::benchkit::{header, Bench};
use rapid::config::SystemConfig;
use rapid::dispatcher::RapidDispatcher;
use rapid::experiments::overhead;
use rapid::robot::{Jv, SensorFrame};
use rapid::util::RollingStats;

fn main() {
    let sys = SystemConfig::default();
    let mut bench = Bench::new().with_budget_ms(1000.0);

    header("rolling statistics");
    let mut rs = RollingStats::new(sys.dispatcher.window_acc);
    let mut i = 0u64;
    bench.run("rolling_stats.push+zscore", || {
        i = i.wrapping_add(1);
        rs.push((i % 17) as f64 * 0.1);
        std::hint::black_box(rs.zscore(1.0, 1e-6));
    });

    header("dispatcher sensor tick (observe)");
    let mut d = RapidDispatcher::new(&sys.dispatcher, 1.0 / sys.robot.sensor_hz);
    let mut step = 0usize;
    bench.run("dispatcher.observe", || {
        step += 1;
        let f = SensorFrame {
            step,
            q: Jv::splat(0.1),
            dq: Jv::splat(0.2 + 0.001 * (step % 7) as f64),
            tau: Jv::splat(1.0 + 0.01 * (step % 5) as f64),
        };
        std::hint::black_box(d.observe(&f));
    });

    header("dispatcher control decision");
    bench.run("dispatcher.decide", || {
        std::hint::black_box(d.decide(false));
    });

    header("sensor budget share (500 Hz => 2 ms/tick)");
    let r = overhead::run(&sys, 0.06);
    println!(
        "tick {:.0}ns = {:.4}% of budget; state {} bytes; system-level overhead share target 5-7%",
        r.tick_ns,
        100.0 * r.tick_budget_frac,
        r.state_bytes
    );
    assert!(r.tick_budget_frac < 0.05, "dispatcher busts the sensor budget");
}

//! Regenerates paper Fig. 5: the pick-and-place case study timeline —
//! where RAPID's offloads land relative to the critical interaction
//! windows ("pick up the banana and put it into the blue bowl").

use rapid::config::presets::libero_preset;
use rapid::experiments::{fig5, Backends};

fn main() {
    let sys = libero_preset();
    let mut backends = Backends::pjrt_or_analytic(sys.episode.seed);
    let t0 = std::time::Instant::now();
    let data = fig5::run(&sys, &mut backends);
    print!("{}", fig5::render_ascii(&data, 72));
    println!("offload steps: {:?}", data.offload_steps);
    println!("critical windows: {:?}", data.critical_windows);
    std::fs::create_dir_all("target/figures").ok();
    data.trace.save_csv("target/figures/fig5_case.csv").unwrap();
    println!("CSV written to target/figures/fig5_case.csv");
    println!("[bench wall-clock {:.1}s]", t0.elapsed().as_secs_f64());
}

//! Regenerates paper Fig. 2: (a) vision-based entropy traces vs threshold
//! under the three noise levels; (b) kinematic score behaviour.
//! Dumps step-aligned CSVs for plotting and prints terminal sparklines.

use rapid::config::presets::libero_preset;
use rapid::experiments::{fig2, Backends};

fn main() {
    let sys = libero_preset();
    let mut backends = Backends::pjrt_or_analytic(sys.episode.seed);
    let t0 = std::time::Instant::now();
    let data = fig2::run(&sys, &mut backends);

    println!("(a) vision-based entropy vs threshold {:.2} nats", data.entropy_threshold);
    for (noise, entropy, phase) in &data.entropy_traces {
        let rate = fig2::false_breach_rate(entropy, phase, data.entropy_threshold);
        println!(
            "  {:<13} false-breach rate in routine motion: {:>5.1}%",
            noise.name(),
            100.0 * rate
        );
    }

    println!("(b) kinematic panel (clean RAPID episode):");
    println!("  tau      {}", data.kinematic.sparkline("tau_norm", 64));
    println!("  velocity {}", data.kinematic.sparkline("velocity", 64));
    println!("  critical {}", data.kinematic.sparkline("critical", 64));
    println!("  offload  {}", data.kinematic.sparkline("offload", 64));

    std::fs::create_dir_all("target/figures").ok();
    data.kinematic.save_csv("target/figures/fig2_kinematic.csv").unwrap();
    println!("CSV written to target/figures/fig2_kinematic.csv");
    println!("[bench wall-clock {:.1}s]", t0.elapsed().as_secs_f64());
}

//! Regenerates paper Fig. 3: correlation between joint-torque variation
//! and step-wise redundancy (attention mass), per task and pooled.
//!
//! Expected shape: clearly positive correlation (the paper's basis for
//! using torque as a lightweight redundancy surrogate).

use rapid::config::presets::libero_preset;
use rapid::experiments::{fig3, Backends};

fn main() {
    let sys = libero_preset();
    let mut backends = Backends::pjrt_or_analytic(sys.episode.seed);
    let t0 = std::time::Instant::now();
    let data = fig3::run(&sys, &mut backends, 4);
    println!("Joint torque variation vs attention mass:");
    for (task, dtau, _, r, rho) in &data.series {
        println!(
            "  {:<16} n={:<5} pearson r = {r:+.3}  spearman = {rho:+.3}",
            task.name(),
            dtau.len()
        );
    }
    println!(
        "  pooled            pearson r = {:+.3}  spearman = {:+.3}",
        data.pooled_pearson, data.pooled_spearman
    );
    println!("positive correlation: {}", data.pooled_pearson > 0.3);
    println!("[bench wall-clock {:.1}s]", t0.elapsed().as_secs_f64());
}

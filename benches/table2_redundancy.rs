//! Regenerates paper Table II: attention distribution and step-wise action
//! redundancy per task (Pick & Place L=50, Drawer L=80, Peg L=60).
//!
//! Expected shape: redundant actions > 80%, W_crit ~ 10x W_red.

use rapid::config::presets::libero_preset;
use rapid::experiments::{tab2, Backends};

fn main() {
    let sys = libero_preset();
    let mut backends = Backends::pjrt_or_analytic(sys.episode.seed);
    let t0 = std::time::Instant::now();
    let (table, rows) = tab2::run(&sys, &mut backends, 4);
    print!("{}", table.render());
    for r in &rows {
        println!(
            "{:<16} redundancy-dominant: {}  attention ratio W_crit/W_red = {:.1}x",
            r.task.name(),
            r.stats.p_red > 0.7,
            r.stats.w_crit / r.stats.w_red.max(1e-9)
        );
    }
    println!("[bench wall-clock {:.1}s]", t0.elapsed().as_secs_f64());
}

//! Regenerates the paper's §VI-D.1 hyper-parameter discussion: the
//! latency/offload trade-off over (θ_comp, θ_red), around the paper's
//! optimum (0.65, 0.35).

use rapid::config::presets::libero_preset;
use rapid::experiments::{sweep, Backends};

fn main() {
    let sys = libero_preset();
    let mut backends = Backends::pjrt_or_analytic(sys.episode.seed);
    let t0 = std::time::Instant::now();
    let (table, points) = sweep::run(
        &sys,
        &mut backends,
        &[0.35, 0.5, 0.65, 0.9, 1.3],
        &[0.2, 0.35, 0.55],
        2,
    );
    print!("{}", table.render());
    let best = points
        .iter()
        .min_by(|a, b| a.total_lat.partial_cmp(&b.total_lat).unwrap())
        .unwrap();
    println!(
        "best total latency {:.1}ms at (theta_comp={:.2}, theta_red={:.2}); paper optimum (0.65, 0.35)",
        best.total_lat, best.theta_comp, best.theta_red
    );
    println!("[bench wall-clock {:.1}s]", t0.elapsed().as_secs_f64());
}

//! Regenerates paper Table V: ablation of the dual-threshold mechanism.
//!
//! Expected shape: full RAPID < w/o θ_comp < w/o θ_red in total latency
//! (removing the torque trigger hurts most — critical interactions are
//! exactly what must go to the cloud).

use rapid::config::presets::libero_preset;
use rapid::config::PolicyKind;
use rapid::experiments::{tab345, Backends};

fn main() {
    let sys = libero_preset();
    let mut backends = Backends::pjrt_or_analytic(sys.episode.seed);
    let t0 = std::time::Instant::now();
    let (table, rows) = tab345::tab5(&sys, &mut backends, 4);
    print!("{}", table.render());
    let full = rows.get(PolicyKind::Rapid).total_lat_mean;
    let no_comp = rows.get(PolicyKind::RapidNoComp).total_lat_mean;
    let no_red = rows.get(PolicyKind::RapidNoRed).total_lat_mean;
    println!("ordering holds (full < no_comp < no_red): {}", full < no_comp && no_comp < no_red);
    println!("[bench wall-clock {:.1}s]", t0.elapsed().as_secs_f64());
}

//! Regenerates paper Table I: vision-based dynamic strategy under
//! Standard / Visual Noise / Distraction.
//!
//! Expected shape (paper): total latency grows 395 → 520 → 685 ms as noise
//! forces more offloads; edge residency shrinks; total load constant.

use rapid::config::presets::libero_preset;
use rapid::experiments::{tab1, Backends};

fn main() {
    let sys = libero_preset();
    let mut backends = Backends::pjrt_or_analytic(sys.episode.seed);
    let t0 = std::time::Instant::now();
    let (table, rows) = tab1::run(&sys, &mut backends, 4);
    print!("{}", table.render());
    println!(
        "shape checks: monotone latency {}; edge shrinks {}; load constant {}",
        rows[0].total_lat < rows[1].total_lat && rows[1].total_lat < rows[2].total_lat,
        rows[2].edge_gb < rows[0].edge_gb,
        rows.iter().all(|r| (r.total_gb - sys.total_model_gb).abs() < 1e-6),
    );
    println!("[bench wall-clock {:.1}s]", t0.elapsed().as_secs_f64());
}

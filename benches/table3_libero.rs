//! Regenerates paper Table III: edge-cloud collaborative inference on the
//! LIBERO simulation preset (Edge-Only / Cloud-Only / SAFE / RAPID).
//!
//! Expected shape: Cloud-Only < RAPID < SAFE < Edge-Only in total latency;
//! RAPID edge footprint 2.4 GB; load columns sum to 14.2 GB.

use rapid::config::presets::libero_preset;
use rapid::experiments::{tab345, Backends};

fn main() {
    let sys = libero_preset();
    let mut backends = Backends::pjrt_or_analytic(sys.episode.seed);
    let t0 = std::time::Instant::now();
    let (table, rows) = tab345::tab3(&sys, &mut backends, 4);
    print!("{}", table.render());
    println!(
        "RAPID speedup vs vision baseline: {:.2}x (paper: 1.69x sim)",
        rows.speedup_vs_vision()
    );
    println!(
        "RAPID speedup vs edge-only: {:.2}x",
        rows.get(rapid::config::PolicyKind::EdgeOnly).total_lat_mean
            / rows.get(rapid::config::PolicyKind::Rapid).total_lat_mean
    );
    println!("[bench wall-clock {:.1}s]", t0.elapsed().as_secs_f64());
}

"""Synthetic observation generator mirroring rust scene::renderer.

Used by the python tests to exercise the model with the same observation
semantics the Rust L3 driver produces (layout documented in model.py).
"""

import numpy as np

from compile import model as M


SCENE_TEXTURE_STD = 0.45  # mirrors rust scene::renderer::SCENE_TEXTURE_STD
CLUTTER_STD = 0.10        # occluders are featureless => low-energy clutter


def make_obs(joint_err, sal_horizon, saliency, clarity=1.0, seed=0,
             scene_seed=1234):
    """Compose an observation vector; clarity in (0,1] attenuates everything
    and is the renderer's model of visual noise/occlusion. The texture
    channels carry a *persistent* scene signature (fixed per scene_seed)
    whose energy scales with clarity."""
    rng = np.random.default_rng(seed)
    scene = np.random.default_rng(scene_seed).normal(
        0.0, SCENE_TEXTURE_STD, M.D_VIS - 16)
    obs = np.zeros(M.D_VIS, np.float32)
    obs[0:M.N_JOINTS] = np.asarray(joint_err, np.float32)
    obs[7:7 + M.CHUNK] = np.asarray(sal_horizon, np.float32)
    obs[15] = saliency
    obs[16:] = scene + rng.normal(0.0, 0.05, M.D_VIS - 16)
    obs *= clarity
    # low-energy clutter replaces the attenuated texture — it does NOT
    # restore the semantic channels or the scene signature.
    obs[16:] += rng.normal(0.0, CLUTTER_STD * (1.0 - clarity), M.D_VIS - 16)
    return obs


def approach_obs(clarity=1.0, seed=0):
    return make_obs([0.3] * 7, [0.02] * 8, 0.05, clarity, seed)


def contact_obs(clarity=1.0, seed=0):
    return make_obs([0.05] * 7, np.linspace(0.3, 1.0, 8), 0.9, clarity, seed)

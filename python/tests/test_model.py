"""L2 correctness + constructed-behaviour checks for the VLA surrogate.

Three behaviour families are load-bearing for the paper's evaluation (see
model.py docstring): action tracking, clarity->entropy monotonicity, and
saliency->attention-mass routing. Each is asserted here so a regression in
the weight construction fails fast in `make test`, before any Rust runs.
"""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from tests import obsgen

PROP = np.zeros(M.D_PROP, np.float32)
INSTR = np.eye(M.N_INSTR, dtype=np.float32)[2]


def fwd(cfg, flat, obs, use_pallas=False, prop=PROP):
    a, l, m = M.forward(cfg, flat, obs, prop, INSTR, use_pallas=use_pallas)
    return np.asarray(a), np.asarray(l), np.asarray(m)


@pytest.fixture(scope="module", params=["edge", "cloud"])
def variant(request):
    cfg = M.CONFIGS[request.param]
    flat = M.flatten_weights(cfg, M.make_weights(cfg, seed=0))
    return cfg, flat


class TestShapes:
    def test_output_shapes(self, variant):
        cfg, flat = variant
        a, l, m = fwd(cfg, flat, obsgen.approach_obs())
        assert a.shape == (M.CHUNK, M.N_JOINTS)
        assert l.shape == (M.CHUNK, M.VOCAB)
        assert m.shape == (M.CHUNK,)

    def test_param_count_matches_flat(self, variant):
        cfg, flat = variant
        assert flat.shape == (M.param_count(cfg),)

    def test_weight_offsets_cover_buffer(self, variant):
        cfg, flat = variant
        offs, total = M.weight_offsets(cfg)
        assert total == flat.size
        ends = sorted(o + int(np.prod(s)) for o, s in offs.values())
        starts = sorted(o for o, _ in offs.values())
        assert starts[0] == 0 and ends[-1] == total

    def test_outputs_finite(self, variant):
        cfg, flat = variant
        for obs in (obsgen.approach_obs(), obsgen.contact_obs(),
                    np.zeros(M.D_VIS, np.float32)):
            a, l, m = fwd(cfg, flat, obs)
            assert np.isfinite(a).all() and np.isfinite(l).all() \
                and np.isfinite(m).all()

    def test_actions_bounded(self, variant):
        cfg, flat = variant
        a, _, _ = fwd(cfg, flat, obsgen.contact_obs(), prop=np.ones(
            M.D_PROP, np.float32))
        assert (np.abs(a) <= 1.0).all()

    def test_mass_nonnegative(self, variant):
        cfg, flat = variant
        for seed in range(5):
            _, _, m = fwd(cfg, flat, obsgen.approach_obs(seed=seed))
            assert (m >= 0).all()


class TestPallasAgreement:
    """Whole-model pallas-vs-reference agreement (beyond per-kernel tests)."""

    def test_forward_matches_reference(self, variant):
        cfg, flat = variant
        obs = obsgen.contact_obs()
        ref = fwd(cfg, flat, obs, use_pallas=False)
        pal = fwd(cfg, flat, obs, use_pallas=True)
        for r, p in zip(ref, pal):
            assert_allclose(p, r, rtol=5e-5, atol=5e-5)


class TestActionTracking:
    def test_actions_follow_joint_error_sign(self, variant):
        cfg, flat = variant
        err = np.array([0.4, -0.4, 0.3, -0.3, 0.2, -0.2, 0.1], np.float32)
        obs = obsgen.make_obs(err, [0.02] * 8, 0.05)
        a, _, _ = fwd(cfg, flat, obs)
        # mean action over the chunk tracks the error direction per joint
        assert (np.sign(a.mean(0)) == np.sign(err)).mean() >= 6 / 7

    def test_zero_error_small_actions(self, variant):
        cfg, flat = variant
        obs = obsgen.make_obs([0.0] * 7, [0.02] * 8, 0.05)
        a, _, _ = fwd(cfg, flat, obs)
        assert np.abs(a).mean() < 0.15

    def test_action_magnitude_scales_with_error(self, variant):
        cfg, flat = variant
        mags = []
        for e in (0.1, 0.3, 0.6):
            obs = obsgen.make_obs([e] * 7, [0.02] * 8, 0.05)
            a, _, _ = fwd(cfg, flat, obs)
            mags.append(np.abs(a.mean(0)).mean())
        assert mags[0] < mags[1] < mags[2]


class TestEntropyBehaviour:
    """The vision-baseline failure mode: noise flattens the distribution."""

    def test_entropy_monotone_in_noise(self, variant):
        cfg, flat = variant
        ents = []
        for clarity in (1.0, 0.7, 0.4, 0.2):
            _, l, _ = fwd(cfg, flat, obsgen.approach_obs(clarity=clarity))
            ents.append(float(np.asarray(M.entropy(l)).mean()))
        assert all(a < b for a, b in zip(ents, ents[1:])), ents

    def test_clean_noisy_separation(self, variant):
        """Clean vs heavily degraded entropy must separate by >= 0.6 nat —
        the margin the SAFE/ISAR threshold sits inside. (Approach-phase
        observations are the *weak-signal* worst case.)"""
        cfg, flat = variant
        _, lc, _ = fwd(cfg, flat, obsgen.approach_obs(clarity=1.0))
        _, ln, _ = fwd(cfg, flat, obsgen.approach_obs(clarity=0.2))
        e_clean = float(np.asarray(M.entropy(lc)).mean())
        e_noisy = float(np.asarray(M.entropy(ln)).mean())
        assert e_noisy - e_clean > 0.6

    def test_entropy_bounded_by_log_vocab(self, variant):
        cfg, flat = variant
        for clarity in (1.0, 0.1):
            _, l, _ = fwd(cfg, flat, obsgen.approach_obs(clarity=clarity))
            e = np.asarray(M.entropy(l))
            assert (e >= 0).all() and (e <= np.log(M.VOCAB) + 1e-4).all()


class TestAttentionMassRouting:
    """Step-wise redundancy instrumentation (Tab. II / Fig. 3)."""

    def test_contact_mass_exceeds_approach_mass(self, variant):
        cfg, flat = variant
        _, _, m_app = fwd(cfg, flat, obsgen.approach_obs())
        _, _, m_con = fwd(cfg, flat, obsgen.contact_obs())
        assert m_con.mean() > 3.0 * m_app.mean()

    def test_mass_tracks_horizon_slot(self, variant):
        """Saliency routed slot i -> action token i: a peaked horizon
        produces a peaked mass profile at the same position."""
        cfg, flat = variant
        hits = 0
        for peak in range(2, M.CHUNK):
            hor = np.full(M.CHUNK, 0.05, np.float32)
            hor[peak] = 1.0
            obs = obsgen.make_obs([0.1] * 7, hor, 0.4)
            _, _, m = fwd(cfg, flat, obs)
            if int(np.argmax(m)) == peak:
                hits += 1
        assert hits >= (M.CHUNK - 2) - 1  # allow one routing miss

    def test_mass_monotone_in_global_saliency(self, variant):
        cfg, flat = variant
        means = []
        for s in (0.1, 0.5, 1.0):
            obs = obsgen.make_obs([0.1] * 7, [s] * 8, s)
            _, _, m = fwd(cfg, flat, obs)
            means.append(m.mean())
        assert means[0] < means[1] < means[2]


class TestDeterminism:
    def test_same_seed_same_weights(self, variant):
        cfg, _ = variant
        f1 = M.flatten_weights(cfg, M.make_weights(cfg, seed=0))
        f2 = M.flatten_weights(cfg, M.make_weights(cfg, seed=0))
        assert np.array_equal(f1, f2)

    def test_different_seed_different_weights(self, variant):
        cfg, _ = variant
        f1 = M.flatten_weights(cfg, M.make_weights(cfg, seed=0))
        f2 = M.flatten_weights(cfg, M.make_weights(cfg, seed=1))
        assert not np.array_equal(f1, f2)

    def test_forward_deterministic(self, variant):
        cfg, flat = variant
        obs = obsgen.contact_obs()
        r1 = fwd(cfg, flat, obs)
        r2 = fwd(cfg, flat, obs)
        for a, b in zip(r1, r2):
            assert np.array_equal(a, b)

"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes per the repo testing policy: the kernels
must be correct for *any* admissible geometry, not just the model's.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.attention import mha
from compile.kernels.mlp import gated_mlp
from compile.kernels.rmsnorm import rmsnorm

F32 = dict(rtol=2e-5, atol=2e-5)


def rand(rng, *shape, dtype=np.float32, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

class TestRmsNorm:
    def test_basic(self):
        rng = np.random.default_rng(0)
        x, g = rand(rng, 18, 64), rand(rng, 64)
        assert_allclose(np.asarray(rmsnorm(x, g)),
                        np.asarray(ref.rmsnorm_ref(x, g)), **F32)

    def test_unit_gamma_normalizes(self):
        rng = np.random.default_rng(1)
        x = rand(rng, 4, 32, scale=7.0)
        y = np.asarray(rmsnorm(x, np.ones(32, np.float32)))
        rms = np.sqrt(np.mean(y * y, axis=-1))
        assert_allclose(rms, np.ones(4), rtol=1e-4, atol=1e-4)

    def test_scale_invariance(self):
        rng = np.random.default_rng(2)
        x, g = rand(rng, 3, 16), rand(rng, 16)
        a = np.asarray(rmsnorm(x, g))
        b = np.asarray(rmsnorm(100.0 * x, g))
        assert_allclose(a, b, rtol=1e-3, atol=1e-4)

    def test_single_row(self):
        rng = np.random.default_rng(3)
        x, g = rand(rng, 1, 8), rand(rng, 8)
        assert_allclose(np.asarray(rmsnorm(x, g)),
                        np.asarray(ref.rmsnorm_ref(x, g)), **F32)

    def test_row_blocking_boundary(self):
        """T not a multiple of block_t exercises the ragged grid tail."""
        rng = np.random.default_rng(4)
        x, g = rand(rng, 130, 16), rand(rng, 16)
        assert_allclose(np.asarray(rmsnorm(x, g, block_t=64)),
                        np.asarray(ref.rmsnorm_ref(x, g)), **F32)

    @settings(max_examples=25, deadline=None)
    @given(t=st.integers(1, 64), d=st.integers(2, 96), seed=st.integers(0, 99))
    def test_hypothesis_shapes(self, t, d, seed):
        rng = np.random.default_rng(seed)
        x, g = rand(rng, t, d), rand(rng, d)
        assert_allclose(np.asarray(rmsnorm(x, g)),
                        np.asarray(ref.rmsnorm_ref(x, g)), **F32)


# ---------------------------------------------------------------------------
# Fused MHA
# ---------------------------------------------------------------------------

class TestMha:
    def _check(self, h, t, dh, seed=0, block_k=128, scale=1.0):
        rng = np.random.default_rng(seed)
        q, k, v = (rand(rng, h, t, dh, scale=scale) for _ in range(3))
        bias = rand(rng, t, t, scale=scale)
        got = np.asarray(mha(q, k, v, bias, block_k=block_k))
        want = np.asarray(ref.mha_ref(q, k, v, bias))
        assert_allclose(got, want, **F32)

    def test_model_geometry_edge(self):
        self._check(4, 18, 16)

    def test_model_geometry_cloud(self):
        self._check(6, 18, 32)

    def test_single_head(self):
        self._check(1, 7, 8)

    def test_single_token(self):
        self._check(2, 1, 4)

    def test_streaming_multiple_k_blocks(self):
        """T > block_k exercises the online-softmax streaming loop."""
        self._check(2, 100, 16, block_k=32)

    def test_streaming_ragged_tail(self):
        """T not a multiple of block_k exercises the tail mask."""
        self._check(2, 37, 8, block_k=16)

    def test_large_bias_dominates(self):
        """Structured-routing regime: bias >> scores => probs ~ one-hot."""
        h, t, dh = 2, 12, 8
        rng = np.random.default_rng(7)
        q, k = rand(rng, h, t, dh, scale=0.01), rand(rng, h, t, dh, scale=0.01)
        v = rand(rng, h, t, dh)
        bias = np.full((t, t), -30.0, np.float32)
        bias[:, 3] = 30.0
        got = np.asarray(mha(q, k, v, bias))
        want = np.broadcast_to(np.asarray(v)[:, 3:4, :], got.shape)
        assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_softmax_stability_large_scores(self):
        self._check(2, 9, 4, scale=30.0)

    def test_permutation_equivariance_over_heads(self):
        rng = np.random.default_rng(8)
        q, k, v = (rand(rng, 3, 10, 8) for _ in range(3))
        bias = rand(rng, 10, 10)
        out = np.asarray(mha(q, k, v, bias))
        perm = [2, 0, 1]
        out_p = np.asarray(mha(q[perm], k[perm], v[perm], bias))
        assert_allclose(out[perm], out_p, **F32)

    @settings(max_examples=20, deadline=None)
    @given(h=st.integers(1, 4), t=st.integers(1, 48),
           dh=st.sampled_from([4, 8, 16]), bk=st.sampled_from([8, 16, 128]),
           seed=st.integers(0, 99))
    def test_hypothesis_shapes(self, h, t, dh, bk, seed):
        self._check(h, t, dh, seed=seed, block_k=bk)


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------

class TestGatedMlp:
    def _check(self, t, d, f, seed=0, block_t=128):
        rng = np.random.default_rng(seed)
        x = rand(rng, t, d)
        w1, w3, w2 = rand(rng, d, f), rand(rng, d, f), rand(rng, f, d)
        got = np.asarray(gated_mlp(x, w1, w3, w2, block_t=block_t))
        want = np.asarray(ref.gated_mlp_ref(x, w1, w3, w2))
        # unit-scale inputs make |y| ~ sqrt(d*f); tolerance is relative to
        # that accumulation scale (XLA may reassociate the reductions)
        assert_allclose(got, want, rtol=2e-3, atol=1e-3)

    def test_model_geometry_edge(self):
        self._check(18, 64, 128)

    def test_model_geometry_cloud(self):
        self._check(18, 192, 384)

    def test_row_blocking(self):
        self._check(100, 16, 32, block_t=32)

    def test_ragged_rows(self):
        self._check(37, 8, 16, block_t=16)

    def test_zero_input_is_zero(self):
        rng = np.random.default_rng(9)
        w1, w3, w2 = rand(rng, 8, 16), rand(rng, 8, 16), rand(rng, 16, 8)
        y = np.asarray(gated_mlp(np.zeros((4, 8), np.float32), w1, w3, w2))
        assert_allclose(y, np.zeros((4, 8)), atol=1e-7, rtol=0)

    @settings(max_examples=20, deadline=None)
    @given(t=st.integers(1, 40), d=st.sampled_from([4, 8, 24]),
           f=st.sampled_from([8, 16, 48]), seed=st.integers(0, 99))
    def test_hypothesis_shapes(self, t, d, f, seed):
        self._check(t, d, f, seed=seed)

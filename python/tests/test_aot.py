"""AOT pipeline checks: HLO text artifacts are parseable interchange and the
lowered computation agrees with the eager forward pass."""

import json
import os

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax

from compile import aot, model as M
from tests import obsgen


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    meta = aot.build(out, seed=0, use_pallas=True)
    return out, meta


class TestArtifacts:
    def test_all_files_exist(self, built):
        out, meta = built
        for v in meta["variants"].values():
            assert os.path.exists(os.path.join(out, v["hlo"]))
            assert os.path.exists(os.path.join(out, v["weights"]))
        assert os.path.exists(os.path.join(out, "meta.json"))

    def test_weights_size_matches_meta(self, built):
        out, meta = built
        for v in meta["variants"].values():
            n = os.path.getsize(os.path.join(out, v["weights"]))
            assert n == 4 * v["n_params"]

    def test_hlo_text_mentions_entry(self, built):
        out, meta = built
        for v in meta["variants"].values():
            head = open(os.path.join(out, v["hlo"])).read(4096)
            assert "HloModule" in head

    def test_meta_roundtrip(self, built):
        out, _ = built
        meta = json.load(open(os.path.join(out, "meta.json")))
        assert set(meta["variants"]) == {"edge", "cloud"}
        assert meta["dims"]["chunk"] == M.CHUNK

    def test_deterministic_weights_hash(self, built):
        """Rebuild with the same seed must give identical weight blobs."""
        import hashlib
        out, meta = built
        for name, cfg in M.CONFIGS.items():
            flat = M.flatten_weights(cfg, M.make_weights(cfg, 0))
            h = hashlib.sha256(flat.astype("<f4").tobytes()).hexdigest()
            assert h == meta["variants"][name]["weights_sha256"]


class TestLoweredNumerics:
    """Compile the lowered module via jax and compare to the eager path —
    the same HLO the Rust PJRT client loads."""

    @pytest.mark.parametrize("name", ["edge", "cloud"])
    def test_lowered_matches_eager(self, name):
        cfg = M.CONFIGS[name]
        flat = M.flatten_weights(cfg, M.make_weights(cfg, 0))
        obs = obsgen.contact_obs()
        prop = np.linspace(-0.5, 0.5, M.D_PROP).astype(np.float32)
        instr = np.eye(M.N_INSTR, dtype=np.float32)[1]

        lowered = aot.lower_variant(cfg, use_pallas=True)
        compiled = lowered.compile()
        got = compiled(flat, obs, prop, instr)
        want = M.forward(cfg, flat, obs, prop, instr, use_pallas=False)
        for g, w in zip(got, want):
            assert_allclose(np.asarray(g), np.asarray(w),
                            rtol=5e-5, atol=5e-5)

    def test_hlo_text_stable_across_lowerings(self):
        a = aot.to_hlo_text(aot.lower_variant(M.EDGE))
        b = aot.to_hlo_text(aot.lower_variant(M.EDGE))
        assert a == b

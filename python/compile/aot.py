"""AOT: lower the VLA surrogate variants to HLO *text* + weight blobs.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 crate links) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (per variant v in {edge, cloud}):
  artifacts/<v>_policy.hlo.txt   — lowered forward pass, tuple output
  artifacts/<v>_weights.bin      — little-endian f32 flat weight buffer
  artifacts/meta.json            — dims, shapes, weight layout, checksums

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import hashlib
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(cfg: M.ModelConfig, use_pallas: bool = True):
    n_params = M.param_count(cfg)

    def fn(wflat, obs, proprio, instr):
        return M.forward(cfg, wflat, obs, proprio, instr,
                         use_pallas=use_pallas)

    specs = (
        jax.ShapeDtypeStruct((n_params,), jnp.float32),
        jax.ShapeDtypeStruct((M.D_VIS,), jnp.float32),
        jax.ShapeDtypeStruct((M.D_PROP,), jnp.float32),
        jax.ShapeDtypeStruct((M.N_INSTR,), jnp.float32),
    )
    return jax.jit(fn).lower(*specs)


def build(outdir: str, seed: int = 0, use_pallas: bool = True) -> dict:
    os.makedirs(outdir, exist_ok=True)
    meta = {
        "seed": seed,
        "pallas": use_pallas,
        "io": {
            "inputs": ["weights[P]", "obs[64]", "proprio[21]", "instr[8]"],
            "outputs": ["actions[8,7]", "logits[8,64]", "mass[8]"],
        },
        "dims": {
            "n_joints": M.N_JOINTS, "chunk": M.CHUNK, "vocab": M.VOCAB,
            "d_vis": M.D_VIS, "d_prop": M.D_PROP, "n_instr": M.N_INSTR,
        },
        "variants": {},
    }
    for name, cfg in M.CONFIGS.items():
        hlo = to_hlo_text(lower_variant(cfg, use_pallas))
        hlo_path = os.path.join(outdir, f"{name}_policy.hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(hlo)

        w = M.make_weights(cfg, seed)
        flat = M.flatten_weights(cfg, w)
        wpath = os.path.join(outdir, f"{name}_weights.bin")
        flat.astype("<f4").tofile(wpath)

        offs, total = M.weight_offsets(cfg)
        meta["variants"][name] = {
            "d": cfg.d, "heads": cfg.heads, "layers": cfg.layers,
            "ffn": cfg.ffn, "seq": cfg.seq, "n_params": total,
            "hlo": os.path.basename(hlo_path),
            "weights": os.path.basename(wpath),
            "weights_sha256": hashlib.sha256(flat.tobytes()).hexdigest(),
            "hlo_bytes": len(hlo),
        }
        print(f"[aot] {name}: {total} params, hlo {len(hlo)/1e6:.2f} MB")
    with open(os.path.join(outdir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference path instead")
    args = ap.parse_args()
    build(args.out, seed=args.seed, use_pallas=not args.no_pallas)


if __name__ == "__main__":
    main()

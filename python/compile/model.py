"""L2: the VLA surrogate model (build-time JAX, calls the Pallas kernels).

Architecture (per DESIGN.md §3): observation encoder -> pre-norm transformer
backbone (RMSNorm / fused MHA / gated MLP, all Pallas) -> chunked action
head. One backbone pass amortizes over a k-step action chunk — this *is* the
paper's action-chunking lever (Eq. 1).

Two variants share the code:
  * ``edge``  — the small model resident on the edge device (2.4 GB slice in
    the paper's bookkeeping),
  * ``cloud`` — the full model served from the cloud (11.8 GB slice).

Outputs per forward pass, consumed by the Rust L3 coordinator:
  * ``actions``   [k, N]  — joint-space action chunk,
  * ``logits``    [k, V]  — action-token logits; their Shannon entropy is the
    vision-based baseline's (SAFE/ISAR) offloading signal,
  * ``attn_mass`` [k]     — per-action-token attention mass, the paper's
    step-wise redundancy instrumentation (Table II / Fig. 3).

Weights are **procedurally constructed**, not trained: a seeded random base
plus structured routing components so the surrogate exhibits the behaviours
the paper's evaluation depends on (see DESIGN.md §3 for the full argument):

  1. action tokens attend to the semantic observation tokens (structured
     attention bias) and the joint-error channels are routed through the
     value path into the action head => actions track the task waypoints;
  2. action-token logits are computed from the *attended visual values*, so
     their magnitude scales with observation clarity => visual noise
     (signal attenuation) flattens the distribution and raises entropy,
     reproducing the failure mode of vision-based partitioning (Tab. I);
  3. the renderer's contact-saliency horizon is routed, slot i -> action
     token i, into the attention-mass head => attention mass peaks at
     critical interaction steps and is near-zero in approach phases
     (Tab. II redundancy stats, Fig. 3 torque correlation).

Observation layout (D_VIS = 64 visual feature channels; produced by the Rust
``scene::renderer`` and mirrored in ``tests/obsgen.py``):
  [0:7)   normalized joint error to the current waypoint
  [7:15)  contact-saliency horizon over the next k steps
  [15]    global interaction saliency
  [16:64) texture channels (scene-hash pseudo-features, clarity-scaled)
"""

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import mlp as mlp_k
from .kernels import rmsnorm as rms_k
from .kernels import ref as ref_k

# ---------------------------------------------------------------------------
# Fixed interface dims (shared with the Rust side through artifacts/meta.json)
# ---------------------------------------------------------------------------
N_JOINTS = 7          # N — DOF of the manipulator
CHUNK = 8             # k — action-chunk length
VOCAB = 64            # V — action-token vocabulary for the entropy signal
D_VIS = 64            # visual feature channels
D_PROP = 3 * N_JOINTS  # proprio: q, q_dot, tau
N_INSTR = 8           # instruction one-hot size
N_VIS_TOK = 8         # visual tokens


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d: int            # model width
    heads: int
    layers: int
    ffn: int
    act_gain: float = 1.2     # action head gain on routed joint error
    logit_gain: float = 16.0  # entropy sharpness on clean observations
    mass_gain: float = 5.0    # saliency -> attention-mass routing gain
    mass_shift: float = 2.0   # softplus shift (baked static constant)
    route_gain: float = 2.0   # encoder semantic routing strength
    bias_gain: float = 6.0    # structured attention-bias strength
    base_scale: float = 0.02  # random base init scale

    @property
    def seq(self) -> int:
        return N_VIS_TOK + 1 + 1 + CHUNK  # visual + proprio + instr + action

    @property
    def dh(self) -> int:
        assert self.d % self.heads == 0
        return self.d // self.heads


EDGE = ModelConfig(name="edge", d=64, heads=4, layers=2, ffn=128,
                   act_gain=0.9, logit_gain=20.0, mass_gain=9.0,
                   mass_shift=3.5)
# base_scale ~ 1/sqrt(d): keeps the random-score noise floor constant across
# widths so the structured routing dominates equally in both variants.
CLOUD = ModelConfig(name="cloud", d=192, heads=6, layers=6, ffn=384,
                    logit_gain=28.0, mass_gain=9.0, mass_shift=3.5,
                    base_scale=0.012)

CONFIGS = {"edge": EDGE, "cloud": CLOUD}


# ---------------------------------------------------------------------------
# Procedural weight construction
# ---------------------------------------------------------------------------

def _weight_spec(cfg: ModelConfig):
    """Ordered (name, shape) list — the flat-buffer layout contract."""
    d, f, t = cfg.d, cfg.ffn, cfg.seq
    spec = [
        ("enc_vis", (N_VIS_TOK, D_VIS, d)),
        ("enc_prop", (D_PROP, d)),
        ("enc_instr", (N_INSTR, d)),
        ("act_query", (CHUNK, d)),
        ("pos", (t, d)),
    ]
    for l in range(cfg.layers):
        spec += [
            (f"l{l}.wqkv", (d, 3 * d)),
            (f"l{l}.wo", (d, d)),
            (f"l{l}.ln", (d,)),
            (f"l{l}.w1", (d, f)),
            (f"l{l}.w3", (d, f)),
            (f"l{l}.w2", (f, d)),
        ]
    spec += [
        ("attn_bias", (t, t)),
        ("head_act", (d, N_JOINTS)),
        ("head_logit", (d, VOCAB)),
        ("head_mass", (CHUNK, d)),
    ]
    return spec


def param_count(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s)) for _, s in _weight_spec(cfg))


def make_weights(cfg: ModelConfig, seed: int = 0):
    """Seeded random base + structured routing. Returns {name: np.ndarray}.

    Seed derivation uses crc32 (NOT builtin hash(), which is randomized per
    process and would make artifacts unreproducible across builds)."""
    import zlib
    rng = np.random.default_rng(seed + zlib.crc32(cfg.name.encode()) % (2 ** 16))
    w = {}
    for name, shape in _weight_spec(cfg):
        w[name] = rng.normal(0.0, cfg.base_scale, size=shape).astype(np.float32)

    d = cfg.d
    g = cfg.route_gain

    # LayerNorm gains start at ~1; the (unstructured) MLP branch is damped
    # by 1/layers so it refines rather than overwrites the routed signal.
    for l in range(cfg.layers):
        w[f"l{l}.ln"] = np.ones(d, np.float32) + w[f"l{l}.ln"]
        w[f"l{l}.w2"] *= 1.0 / cfg.layers

    # -- Encoder semantic routing -------------------------------------------
    # visual token 0 <- joint-error channels (obs[0:7])  -> dims [0:7)
    for j in range(N_JOINTS):
        w["enc_vis"][0, j, j] += g
    # visual token 1 <- saliency horizon (obs[7:15))     -> dims [8:16)
    for i in range(CHUNK):
        w["enc_vis"][1, 7 + i, 8 + i] += g
    # visual token 2 <- global saliency (obs[15])        -> dim 16
    w["enc_vis"][2, 15, 16] += g
    # visual tokens 3.. read the persistent scene texture with amplified
    # random projections: scene-content energy (i.e. clarity) survives to
    # the logit path even when the semantic channels are quiet (a clear
    # scene keeps the model confident after the arm has converged)
    for tok in range(3, N_VIS_TOK):
        w["enc_vis"][tok, 16:, :] *= 10.0
        # ...but keep the texture projection out of the semantic dims
        # [0:17): those carry the routed joint-error / saliency signals, and
        # a large constant texture component there would bias the action
        # and mass heads for the whole episode.
        w["enc_vis"][tok, 16:, :17] = 0.0
    # proprio token routes torque (obs channels 14:21 of proprio = tau)
    for j in range(N_JOINTS):
        w["enc_prop"][2 * N_JOINTS + j, 17 + (j % (d - 17))] += 0.3 * g

    # -- Structured attention bias: action queries attend to semantics ------
    t = cfg.seq
    a0 = N_VIS_TOK + 2  # first action-token row
    bias = w["attn_bias"] * 0.1
    for i in range(CHUNK):
        bias[a0 + i, 0] += cfg.bias_gain        # joint-error token
        bias[a0 + i, 1] += cfg.bias_gain        # saliency-horizon token
        bias[a0 + i, 2] += 0.5 * cfg.bias_gain  # global saliency token
        bias[a0 + i, N_VIS_TOK] += 0.5 * cfg.bias_gain  # proprio token
        for tok in range(3, N_VIS_TOK):         # scene-texture tokens
            bias[a0 + i, tok] += 0.7 * cfg.bias_gain
    w["attn_bias"] = bias.astype(np.float32)

    # -- Value/output path near-identity so routed channels survive ---------
    # The attention branch is *unnormalized* (see forward): per-layer output
    # identity is 1/L so the routed signal sums to ~1x across the stack.
    for l in range(cfg.layers):
        wqkv = w[f"l{l}.wqkv"]
        wqkv[:, 2 * d:3 * d] += np.eye(d, dtype=np.float32)
        w[f"l{l}.wqkv"] = wqkv
        w[f"l{l}.wo"] += (1.0 / cfg.layers) * np.eye(d, dtype=np.float32)

    # -- Heads ---------------------------------------------------------------
    # action head: dims [0:7) (routed joint error) -> joints, tanh outside.
    for j in range(N_JOINTS):
        w["head_act"][j, j] += cfg.act_gain
    # logit head: random but scaled so clean observations give peaked logits.
    w["head_logit"] = (rng.normal(0.0, 1.0, size=(d, VOCAB)).astype(np.float32)
                       * cfg.logit_gain / np.sqrt(d))
    # mass head: per-token selector on the routed saliency-horizon slot.
    w["head_mass"] *= 0.1
    for i in range(CHUNK):
        w["head_mass"][i, 8 + i] += cfg.mass_gain
        w["head_mass"][i, 16] += 0.3 * cfg.mass_gain
    return w


def flatten_weights(cfg: ModelConfig, w) -> np.ndarray:
    return np.concatenate([np.asarray(w[name], np.float32).ravel()
                           for name, _ in _weight_spec(cfg)])


def weight_offsets(cfg: ModelConfig):
    """{name: (offset, shape)} into the flat f32 buffer."""
    out, off = {}, 0
    for name, shape in _weight_spec(cfg):
        n = int(np.prod(shape))
        out[name] = (off, shape)
        off += n
    return out, off


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _unflatten(cfg: ModelConfig, flat):
    offs, total = weight_offsets(cfg)
    w = {}
    for name, (off, shape) in offs.items():
        n = int(np.prod(shape))
        w[name] = jnp.reshape(
            jnp.asarray(flat)[off:off + n].astype(jnp.float32), shape)
    return w


def _attention(cfg, x, wqkv, wo, bias, use_pallas):
    t, d = x.shape
    qkv = x @ wqkv                                    # [T, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(m):
        return jnp.transpose(jnp.reshape(m, (t, cfg.heads, cfg.dh)), (1, 0, 2))

    qh, kh, vh = heads(q), heads(k), heads(v)
    if use_pallas:
        oh = attn_k.mha(qh, kh, vh, bias)
    else:
        oh = ref_k.mha_ref(qh, kh, vh, bias)
    o = jnp.reshape(jnp.transpose(oh, (1, 0, 2)), (t, d))
    return o @ wo


def forward(cfg: ModelConfig, weights, obs, proprio, instr,
            use_pallas: bool = True):
    """VLA surrogate forward pass.

    weights: flat f32 [P] (or dict); obs: [D_VIS]; proprio: [D_PROP];
    instr: [N_INSTR] one-hot. Returns (actions [k,N], logits [k,V], mass [k]).
    """
    w = weights if isinstance(weights, dict) else _unflatten(cfg, weights)
    w = {k_: jnp.asarray(v) for k_, v in w.items()}

    obs = jnp.asarray(obs, jnp.float32)
    proprio = jnp.asarray(proprio, jnp.float32)
    instr = jnp.asarray(instr, jnp.float32)

    vis_tok = jnp.einsum("c,tcd->td", obs, w["enc_vis"])      # [8, d]
    prop_tok = (proprio @ w["enc_prop"])[None, :]             # [1, d]
    instr_tok = (instr @ w["enc_instr"])[None, :]             # [1, d]
    x = jnp.concatenate([vis_tok, prop_tok, instr_tok, w["act_query"]], 0)
    x = x + w["pos"]

    rms = rms_k.rmsnorm if use_pallas else ref_k.rmsnorm_ref
    mlp = mlp_k.gated_mlp if use_pallas else ref_k.gated_mlp_ref

    # Norm-free attention path (scale-carrying: observation clarity must
    # survive to the heads — see module docstring (2)), normed MLP path.
    for l in range(cfg.layers):
        a = _attention(cfg, x, w[f"l{l}.wqkv"], w[f"l{l}.wo"],
                       w["attn_bias"], use_pallas)
        x = x + a
        h2 = rms(x, w[f"l{l}.ln"])
        x = x + mlp(h2, w[f"l{l}.w1"], w[f"l{l}.w3"], w[f"l{l}.w2"])

    # All heads read the residual stream of the action rows: it accumulates
    # the routed, clarity-scaled attention values across every layer (the
    # obs-independent query/pos constants are an order of magnitude smaller).
    a0 = N_VIS_TOK + 2
    h_act = x[a0:a0 + CHUNK]

    actions = jnp.tanh(h_act @ w["head_act"])                 # [k, N]
    logits = h_act @ w["head_logit"]                          # [k, V]
    mass = jnp.sum(w["head_mass"] * h_act, axis=-1)           # [k]
    mass = jnp.log1p(jnp.exp(mass - cfg.mass_shift))          # softplus >= 0
    return actions, logits, mass


def entropy(logits):
    """Shannon entropy (nats) per row — mirrors rust vla::entropy."""
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(z)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return -jnp.sum(p * jnp.log(p + 1e-12), axis=-1)

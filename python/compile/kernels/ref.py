"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match its oracle to float32 tolerance under ``interpret=True``.
The oracles are also used by ``model.py`` (``use_pallas=False``) so the whole
L2 forward pass can be cross-checked kernel-vs-reference end to end.
"""

import jax.numpy as jnp


def rmsnorm_ref(x, gamma, eps: float = 1e-6):
    """RMSNorm: y = x / rms(x) * gamma, row-wise over the last axis."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jnp.reciprocal(jnp.sqrt(ms + eps)) * gamma


def mha_ref(q, k, v, bias):
    """Multi-head attention core.

    q, k, v: [H, T, Dh]; bias: [T, T] additive attention bias (shared across
    heads — RAPID uses it for the structured routing prior).
    Returns [H, T, Dh].
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    scores = jnp.einsum("htd,hsd->hts", q, k) * scale + bias[None, :, :]
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("hts,hsd->htd", probs, v)


def silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def gated_mlp_ref(x, w1, w3, w2):
    """Gated (SwiGLU-style) MLP: y = (silu(x @ w1) * (x @ w3)) @ w2."""
    return (silu(x @ w1) * (x @ w3)) @ w2

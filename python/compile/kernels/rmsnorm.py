"""RMSNorm as a Pallas kernel (row-blocked over the token axis)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_t"))
def rmsnorm(x, gamma, eps: float = 1e-6, block_t: int = 128):
    """y = x / rms(x) * gamma with x: [T, D], gamma: [D]."""
    t, d = x.shape
    bt = min(block_t, t)
    grid = ((t + bt - 1) // bt,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, gamma)

"""Fused gated MLP (SwiGLU) as a Pallas kernel.

Fuses both up-projections, the gate nonlinearity, and the down-projection in
one VMEM round-trip: the activation tile never returns to HBM between the
three matmuls. Grid is over row blocks of the token axis so the kernel
scales to long sequences; weights are small enough (d x f) to resident-load
per program (the surrogate dims keep W under the ~1 MiB VMEM budget noted
in DESIGN.md §8).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    w1 = w1_ref[...].astype(jnp.float32)
    w3 = w3_ref[...].astype(jnp.float32)
    w2 = w2_ref[...].astype(jnp.float32)
    up = x @ w1
    gate = up * (1.0 / (1.0 + jnp.exp(-up)))  # silu
    y = (gate * (x @ w3)) @ w2
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t",))
def gated_mlp(x, w1, w3, w2, block_t: int = 128):
    """y = (silu(x @ w1) * (x @ w3)) @ w2 with x: [T, D]."""
    t, d = x.shape
    f = w1.shape[1]
    bt = min(block_t, t)
    grid = ((t + bt - 1) // bt,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, w1, w3, w2)

"""Fused multi-head attention as a Pallas kernel (the VLA compute hot-spot).

TPU mental model (see DESIGN.md §2 / §Hardware-Adaptation):

* grid is over heads; each program owns one head's [T, Dh] tiles in VMEM;
* K/V are streamed in blocks of ``block_k`` rows with an **online softmax**
  (running row-max `m` and normalizer `l`), i.e. the flash-attention
  HBM->VMEM schedule expressed with BlockSpec-shaped loads instead of CUDA
  threadblocks;
* accumulation is f32 regardless of input dtype (MXU-friendly).

Executed with ``interpret=True`` — real-TPU lowering would emit a Mosaic
custom-call the CPU PJRT plugin cannot run; numerics are validated through
the interpret path against ``ref.mha_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _mha_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, block_k: int):
    """One head per program: online-softmax attention over K/V blocks."""
    q = q_ref[...].astype(jnp.float32)  # [T, Dh]
    t = q.shape[0]
    dh = q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    n_blocks = (t + block_k - 1) // block_k

    # Running statistics for the online softmax.
    m0 = jnp.full((t, 1), NEG_INF, jnp.float32)       # row max
    l0 = jnp.zeros((t, 1), jnp.float32)               # row normalizer
    acc0 = jnp.zeros((t, dh), jnp.float32)            # weighted V accumulator

    def body(i, carry):
        m, l, acc = carry
        start = i * block_k
        kb = pl.load(k_ref, (pl.dslice(start, block_k), slice(None)))
        vb = pl.load(v_ref, (pl.dslice(start, block_k), slice(None)))
        bb = pl.load(bias_ref, (slice(None), pl.dslice(start, block_k)))
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        # Mask the ragged tail (block may run past T; dslice clamps, so mask
        # by absolute column index).
        cols = start + jax.lax.iota(jnp.int32, block_k)
        valid = (cols < t)[None, :]
        s = q @ kb.T * scale + bb.astype(jnp.float32)      # [T, BK]
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + p @ vb
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[...] = (acc / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k",))
def mha(q, k, v, bias, block_k: int = 128):
    """Multi-head attention core. q,k,v: [H, T, Dh]; bias: [T, T] -> [H, T, Dh]."""
    h, t, dh = q.shape
    bk = min(block_k, t)
    # Pad the K/V/bias key axis to a block multiple: block loads then never
    # run past the buffer (pl.dslice clamps the *start* on overrun, which
    # would desynchronize the kernel's absolute-column mask).
    tk = ((t + bk - 1) // bk) * bk
    if tk != t:
        pad = [(0, 0), (0, tk - t), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        bias = jnp.pad(bias, [(0, 0), (0, tk - t)])
    kernel = functools.partial(_mha_kernel, block_k=bk)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((None, t, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, tk, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, tk, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((t, tk), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((None, t, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, dh), q.dtype),
        interpret=True,
    )(q, k, v, bias)
